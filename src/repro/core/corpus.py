"""Synthetic GEC corpus with NUCLE-like statistics.

NUCLE 3.2 itself is licensed data, so the generator reproduces the paper's
reported corpus statistics instead: 50 essays / 1312 sentences / 30144
tokens (≈23 tokens per sentence) with *low error frequency* ("explained by
the greater proficiency of university students"). Clean sentences come from
a phrase-bank Markov source; corruptions are the exact inverses of the tag
operations, so gold edit tags are derivable by construction:

  drop token w        -> gold APPEND_w on the previous token
  substitute w -> w'  -> gold REPLACE_w on the corrupted token
  insert spurious w'  -> gold DELETE on the inserted token
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.tags import KEEP, TagVocab


@dataclasses.dataclass
class CorpusConfig:
    vocab_size: int = 8192          # model token vocabulary
    edit_words: int = 512           # K most frequent words usable in edits
    n_sentences: int = 1312         # NUCLE test set size
    mean_len: int = 23              # 30144 tokens / 1312 sentences
    error_rate: float = 0.08        # low error frequency
    seed: int = 0


class GECCorpus:
    def __init__(self, cc: CorpusConfig):
        self.cc = cc
        self.vocab = TagVocab(cc.edit_words, token_offset=2)
        rng = np.random.default_rng(cc.seed)
        # frequent words (the editable set) are ids [2, 2+edit_words)
        ranks = np.arange(1, cc.vocab_size + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.phrases = rng.integers(2, 2 + cc.edit_words, (256, 6))
        self.rng = rng

    # ------------------------------------------------------------ sampling
    def _clean_sentence(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, corruptible) — corruptible marks phrase-interior
        positions (offset >= 2), where the phrase prefix identifies the
        phrase and therefore the correction. Errors on free (unigram) tokens
        would be unrecoverable from context — like a proper-noun typo with
        no reference — so the generator keeps the error model inside the
        'grammar' (the phrase bank), mirroring how real grammatical errors
        are recoverable from linguistic context."""
        cc = self.cc
        length = max(5, int(self.rng.normal(cc.mean_len, 6)))
        toks: List[np.ndarray] = []
        corr: List[np.ndarray] = []
        while sum(map(len, toks)) < length:
            if self.rng.random() < 0.8:
                ph = self.phrases[self.rng.integers(len(self.phrases))]
                toks.append(ph)
                c = np.zeros(len(ph), bool)
                c[2:] = True
                corr.append(c)
            else:
                n = self.rng.integers(2, 6)
                toks.append(self.rng.choice(cc.vocab_size, size=n,
                                            p=self.unigram))
                corr.append(np.zeros(n, bool))
        return (np.concatenate(toks)[:length].astype(np.int64),
                np.concatenate(corr)[:length])

    def _corrupt(self, clean: np.ndarray,
                 corruptible: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (src_tokens, gold_tags) aligned per source token."""
        cc, v = self.cc, self.vocab
        src: List[int] = []
        tags: List[int] = []
        i = 0
        while i < len(clean):
            tok = int(clean[i])
            r = self.rng.random()
            editable = corruptible[i] and 2 <= tok < 2 + cc.edit_words
            if r < cc.error_rate / 3 and editable and src \
                    and tags[-1] == KEEP:
                # drop this clean token -> APPEND on previous source token
                tags[-1] = v.append(tok)
                i += 1
                continue
            if r < 2 * cc.error_rate / 3 and editable:
                # substitute -> REPLACE_orig on the corrupted token
                wrong = int(self.rng.integers(2, 2 + cc.edit_words))
                src.append(wrong)
                tags.append(v.replace(tok))
                i += 1
                continue
            if r < cc.error_rate and editable:
                # insert a spurious token -> DELETE
                spur = int(self.rng.integers(2, 2 + cc.edit_words))
                src.append(spur)
                tags.append(1)  # DELETE
                # do not consume the clean token
                continue
            src.append(tok)
            tags.append(KEEP)
            i += 1
        return np.array(src, np.int64), np.array(tags, np.int64)

    # ------------------------------------------------------------ datasets
    def generate(self, n: int = None):
        """Yields (src, gold_tags, clean) triples."""
        n = n or self.cc.n_sentences
        for _ in range(n):
            clean, corruptible = self._clean_sentence()
            src, tags = self._corrupt(clean, corruptible)
            yield src, tags, clean

    def batches(self, batch_size: int, seq_len: int, n_batches: int):
        """Padded training batches: tokens (B,S), tags (B,S), mask (B,S)."""
        gen = self.generate(batch_size * n_batches)
        for _ in range(n_batches):
            toks = np.zeros((batch_size, seq_len), np.int32)
            tags = np.zeros((batch_size, seq_len), np.int32)
            mask = np.zeros((batch_size, seq_len), bool)
            for b in range(batch_size):
                src, gt, _ = next(gen)
                L = min(len(src), seq_len)
                toks[b, :L] = src[:L]
                tags[b, :L] = gt[:L]
                mask[b, :L] = True
            yield {"tokens": toks, "tags": tags, "mask": mask}

    def stats(self, n: int = None) -> dict:
        tot_tok = tot_err = n_sent = 0
        for src, tags, _ in self.generate(n):
            tot_tok += len(src)
            tot_err += int(np.sum(tags != KEEP))
            n_sent += 1
        return {"sentences": n_sent, "tokens": tot_tok,
                "tokens_per_sentence": tot_tok / n_sent,
                "error_rate": tot_err / tot_tok}
