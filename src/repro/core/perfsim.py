"""Analytical performance model of the paper's 21 cloud scenarios.

We cannot provision AWS/GCP/Azure from this container (the hardware gate
flagged by the repro band), so the paper's *measurement* is reproduced as a
calibrated model: for every machine we fit

    latency(NS) = t0 + NS**alpha / R          (R = sentences/s throughput)
    vcpu(NS)    = min(100, c0 + NS * beta)
    ram(NS)     = const

against the paper's own published cells (environments.MEASURED), then (a)
validate goodness-of-fit per machine, and (b) regress the fitted throughput
R against hardware features (vCPUs, cache GB, clock, GPU) to test the
paper's headline interpretation — cache size is the dominant non-GPU factor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.environments import (MACHINES, MEASURED, NS_LADDER,
                                     PROVIDERS, instance)


@dataclasses.dataclass
class MachineModel:
    provider: str
    machine: str
    t0: float
    rate: float          # sentences/s
    alpha: float
    cpu0: float
    cpu_slope: float
    ram_mean: float
    ram_std: float
    mape: float          # latency fit error

    def predict_latency(self, ns) -> np.ndarray:
        ns = np.asarray(ns, float)
        return self.t0 + ns ** self.alpha / self.rate

    def predict_vcpu(self, ns) -> np.ndarray:
        ns = np.asarray(ns, float)
        return np.minimum(100.0, self.cpu0 + ns * self.cpu_slope)

    def predict_ram(self, ns) -> np.ndarray:
        return np.full_like(np.asarray(ns, float), self.ram_mean)


def _fit_latency(ns: np.ndarray, lat: np.ndarray):
    """Grid over alpha; (t0, 1/R) by non-negative least squares on each."""
    best = None
    for alpha in np.linspace(0.5, 1.5, 41):
        X = np.stack([np.ones_like(ns), ns ** alpha], axis=1)
        coef, *_ = np.linalg.lstsq(X, lat, rcond=None)
        t0, inv_r = max(coef[0], 0.0), max(coef[1], 1e-6)
        pred = t0 + ns ** alpha * inv_r
        mape = float(np.mean(np.abs(pred - lat) / np.maximum(lat, 0.1)))
        if best is None or mape < best[0]:
            best = (mape, t0, 1.0 / inv_r, alpha)
    return best  # (mape, t0, rate, alpha)


def fit_machine(provider: str, machine: str) -> MachineModel:
    cells = MEASURED[provider][machine]
    ns = np.array(NS_LADDER, float)
    lat = np.array([cells[n][0] for n in NS_LADDER])
    cpu = np.array([cells[n][1] for n in NS_LADDER])
    ram = np.array([cells[n][2] for n in NS_LADDER])
    mape, t0, rate, alpha = _fit_latency(ns, lat)
    # cpu: fit on the unsaturated region only
    unsat = cpu < 95
    X = np.stack([np.ones(unsat.sum()), ns[unsat]], axis=1)
    coef, *_ = np.linalg.lstsq(X, cpu[unsat], rcond=None)
    return MachineModel(provider, machine, t0, rate, alpha,
                        float(max(coef[0], 0.0)), float(max(coef[1], 0.0)),
                        float(ram.mean()), float(ram.std()), mape)


def fit_all() -> Dict[str, Dict[str, MachineModel]]:
    return {p: {m: fit_machine(p, m) for m in MACHINES} for p in PROVIDERS}


def validation_summary(models=None) -> dict:
    models = models or fit_all()
    mapes = {f"{p}/{m}": models[p][m].mape
             for p in PROVIDERS for m in MACHINES}
    return {"per_machine_mape": mapes,
            "mean_mape": float(np.mean(list(mapes.values()))),
            "max_mape": float(np.max(list(mapes.values())))}


def throughput_feature_regression(models=None) -> dict:
    """Standardized OLS of log-throughput on (vcpus, cache, clock, gpu) over
    the 21 machines. The paper's claim predicts cache carries the largest
    standardized non-GPU coefficient."""
    models = models or fit_all()
    rows, y = [], []
    for p in PROVIDERS:
        for m in MACHINES:
            inst = instance(p, m)
            rows.append([inst.vcpus, inst.cache_gb or 0.0, inst.clock_ghz,
                         1.0 if inst.gpu else 0.0])
            y.append(np.log(models[p][m].rate))
    X = np.array(rows)
    y = np.array(y)
    mu, sd = X.mean(0), X.std(0) + 1e-9
    Xs = (X - mu) / sd
    Xs = np.concatenate([np.ones((len(y), 1)), Xs], axis=1)
    coef, res, *_ = np.linalg.lstsq(Xs, y, rcond=None)
    pred = Xs @ coef
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    names = ["intercept", "vcpus", "cache_gb", "clock_ghz", "gpu"]
    return {"coef": dict(zip(names, map(float, coef))),
            "r2": 1 - ss_res / ss_tot}


def cpu_only_feature_regression(models=None) -> dict:
    """Same regression restricted to the 15 CPU machines (A–E)."""
    models = models or fit_all()
    rows, y = [], []
    for p in PROVIDERS:
        for m in "ABCDE":
            inst = instance(p, m)
            rows.append([inst.vcpus, inst.cache_gb, inst.clock_ghz])
            y.append(np.log(models[p][m].rate))
    X = np.array(rows)
    y = np.array(y)
    mu, sd = X.mean(0), X.std(0) + 1e-9
    Xs = np.concatenate([np.ones((len(y), 1)), (X - mu) / sd], axis=1)
    coef, *_ = np.linalg.lstsq(Xs, y, rcond=None)
    pred = Xs @ coef
    r2 = 1 - float(np.sum((y - pred) ** 2)) / float(np.sum((y - y.mean()) ** 2))
    names = ["intercept", "vcpus", "cache_gb", "clock_ghz"]
    return {"coef": dict(zip(names, map(float, coef))), "r2": r2}
