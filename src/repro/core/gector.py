"""GECToR in JAX (Omelianchuk et al., 2020) — the paper's deployed model.

A bidirectional transformer encoder (BERT-style: learned absolute positions,
LayerNorm, GELU — configs/gector_base.py) "stacked with two linear layers
with a softmax layer on top": an error-*detection* head and an edit-*tag*
head. Inference is iterative: predict tags, apply edits, re-run, for up to
``max_iters`` rounds or until every tag is KEEP — exactly the GECToR serving
loop the paper load-tests.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tags import KEEP, TagVocab, apply_edits
from repro.models import forward, init_params
from repro.models.layers import dense_init, split_keys


def init_gector(cfg, rng, tag_vocab: TagVocab):
    ks = split_keys(rng, 3)
    params = {"encoder": init_params(cfg, ks[0])}
    params["detect_head"] = {
        "w": dense_init(ks[1], (cfg.d_model, 2), cfg.d_model, jnp.float32)}
    params["label_head"] = {
        "w": dense_init(ks[2], (cfg.d_model, tag_vocab.n_tags), cfg.d_model,
                        jnp.float32)}
    return params


def gector_forward(cfg, params, tokens, mask=None):
    """tokens: (B, S) -> (tag_logits (B,S,T), detect_logits (B,S,2))."""
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32),
                           tokens.shape)
    hid, _, _ = forward(cfg, params["encoder"], tokens=tokens, positions=pos,
                        causal=False, return_hidden=True)
    hid = hid.astype(jnp.float32)
    tag_logits = hid @ params["label_head"]["w"]
    det_logits = hid @ params["detect_head"]["w"]
    return tag_logits, det_logits


def gector_loss(cfg, params, batch, *, keep_weight: float = 0.2):
    """CE on edit tags + CE on the binary detect head (paper architecture);
    masked by valid tokens. KEEP is downweighted (GECToR's class-imbalance
    handling: ~90% of tokens are correct, so an unweighted loss collapses to
    the all-KEEP predictor)."""
    tags = batch["tags"]
    mask = batch["mask"]
    tag_logits, det_logits = gector_forward(cfg, params, batch["tokens"])
    logp = jax.nn.log_softmax(tag_logits, axis=-1)
    nll_tag = -jnp.take_along_axis(logp, tags[..., None], axis=-1)[..., 0]
    w = jnp.where(tags == KEEP, keep_weight, 1.0) * mask
    det_target = (tags != KEEP).astype(jnp.int32)
    logp_d = jax.nn.log_softmax(det_logits, axis=-1)
    nll_det = -jnp.take_along_axis(logp_d, det_target[..., None],
                                   axis=-1)[..., 0]
    denom = jnp.maximum(w.sum(), 1e-6)
    loss = jnp.sum((nll_tag + 0.5 * nll_det) * w) / denom
    denom_m = jnp.maximum(mask.sum(), 1)
    acc = jnp.sum((jnp.argmax(tag_logits, -1) == tags) * mask) / denom_m
    edit_mask = (tags != KEEP) & mask
    edit_acc = (jnp.sum((jnp.argmax(tag_logits, -1) == tags) * edit_mask)
                / jnp.maximum(edit_mask.sum(), 1))
    return loss, {"tag_acc": acc, "edit_acc": edit_acc}


#: module-level jit so predict_tags reuses one compile cache across calls
#: (an inline jax.jit(...)(...) here rebuilt the wrapper — and recompiled —
#: on every batch; the repro-lint `recompile` pass guards the pattern now)
_jit_gector_forward = jax.jit(gector_forward, static_argnums=0)


def predict_tags(cfg, params, tokens_batch: np.ndarray,
                 mask: np.ndarray, *, min_error_prob: float = 0.0):
    """Argmax tags, optionally gated by the detect head (GECToR's
    confidence-bias trick)."""
    tag_logits, det_logits = _jit_gector_forward(
        cfg, params, jnp.asarray(tokens_batch))
    tags = np.asarray(jnp.argmax(tag_logits, -1))
    if min_error_prob > 0:
        perr = np.asarray(jax.nn.softmax(det_logits, -1))[..., 1]
        tags = np.where(perr >= min_error_prob, tags, KEEP)
    return np.where(mask, tags, KEEP)


def iterative_correct(cfg, params, vocab: TagVocab,
                      sentences: Sequence[np.ndarray], *, max_iters: int = 4,
                      max_len: int = 128) -> List[np.ndarray]:
    """The GECToR inference loop: tag -> apply -> repeat while edits fire."""
    current = [np.asarray(s)[:max_len] for s in sentences]
    active = list(range(len(current)))
    for _ in range(max_iters):
        if not active:
            break
        L = max(len(current[i]) for i in active)
        L = min(max(L, 1), max_len)
        toks = np.zeros((len(active), L), np.int32)
        msk = np.zeros((len(active), L), bool)
        for row, i in enumerate(active):
            n = min(len(current[i]), L)
            toks[row, :n] = current[i][:n]
            msk[row, :n] = True
        tags = predict_tags(cfg, params, toks, msk)
        still = []
        for row, i in enumerate(active):
            n = int(msk[row].sum())
            if np.all(tags[row, :n] == KEEP):
                continue
            current[i] = np.array(
                apply_edits(vocab, toks[row, :n], tags[row, :n]),
                np.int64)[:max_len]
            still.append(i)
        active = still
    return current
