"""Load-test client — the paper's simulation flow (Fig. 7) against our
engine: submit 2^N concurrent sentences (N = 0..9), repeat R times, record
latency plus host CPU%/RAM% sampled from /proc (the Prometheus role).

The /proc samplers live in ``repro.deploy.telemetry`` (the deployment
lab's generalized ring-buffer sampler); this module imports the aggregate
``CpuSampler`` view back from there.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.environments import NS_LADDER
from repro.deploy.telemetry import CpuSampler, read_ram_pct  # noqa: F401


def _ram_pct() -> float:
    pct = read_ram_pct()
    return 0.0 if pct is None else pct


@dataclasses.dataclass
class LoadCell:
    ns: int
    latency_s: float        # mean completion wall time of the batch
    latency_p95_s: float
    vcpu_pct: float
    ram_pct: float
    repeats: int


def run_ladder(engine, sentences: Sequence[np.ndarray], *,
               ladder=NS_LADDER, repeats: int = 3,
               rng_seed: int = 0, warmup: bool = True) -> List[LoadCell]:
    """For each NS on the ladder: fire NS sentences simultaneously at the
    engine, wait for all, measure wall latency; repeat; tabulate — the
    paper's Tables 2-4 procedure (theirs: 10 repeats on real clouds)."""
    rng = np.random.default_rng(rng_seed)
    if warmup:  # exclude jit compilation from the first ladder cell
        engine.submit(sentences[0]).result(timeout=600)
        # drop the compile-laden warmup samples (wall latencies, batch
        # sizes, phase timings) and re-sync the window cursor — one
        # engine-owned definition of "discard", shared with the
        # deploy-lab factory and the benches
        discard = getattr(engine, "discard_samples", None)
        if discard is not None:
            discard()
    cells = []
    for ns in ladder:
        lats = []
        with CpuSampler() as cpu:
            for _ in range(repeats):
                idx = rng.integers(0, len(sentences), ns)
                batch = [sentences[i] for i in idx]
                t0 = time.perf_counter()
                futs = [engine.submit(s) for s in batch]
                for f in futs:
                    f.result(timeout=600)
                lats.append(time.perf_counter() - t0)
        cells.append(LoadCell(ns=ns, latency_s=float(np.mean(lats)),
                              latency_p95_s=float(np.percentile(lats, 95)),
                              vcpu_pct=cpu.mean, ram_pct=_ram_pct(),
                              repeats=repeats))
    return cells


def mixed_bucket_prompts(buckets: Sequence[int], n: int, vocab_size: int, *,
                         rng_seed: int = 0, min_len: int = 3) -> List:
    """Prompt pool spanning every pad bucket: prompt i pads to
    ``buckets[i % len(buckets)]`` (its length drawn from that bucket's
    exclusive band), so consecutive staggered arrivals alternate buckets —
    the mixed-length traffic shape the paper's corpus actually has, and
    the workload where multi-lane scheduling removes the cross-bucket
    head-of-line wait the single-set scheduler pays."""
    buckets = sorted(buckets)
    rng = np.random.default_rng(rng_seed)
    prompts = []
    for i in range(n):
        j = i % len(buckets)
        lo = buckets[j - 1] + 1 if j else min(min_len, buckets[0])
        prompts.append(rng.integers(0, vocab_size,
                                    (int(rng.integers(lo, buckets[j] + 1)),)))
    return prompts


@dataclasses.dataclass
class StaggeredResult:
    """Open-loop (staggered-arrival) load result: the per-request view the
    ladder's batch-synchronous cells can't give — including the mean
    queue/prefill/decode split each ``RequestTiming`` already carries, so a
    latency regression is attributable to a phase without re-running."""
    n_requests: int
    gap_s: float                  # inter-arrival gap (offered load knob)
    latency_p50_s: float
    latency_p95_s: float
    wall_s: float
    total_tokens: int
    queue_mean_s: float = 0.0     # phase split (means over requests)
    prefill_mean_s: float = 0.0
    decode_mean_s: float = 0.0
    queue_p95_s: float = 0.0      # the head-of-line tail specifically
    # per-request GenerationResults, request-arrival order — only kept
    # when run_staggered(keep_results=True): lets per-class analyses
    # (e.g. bench_segment_width's long-request split) reuse this runner
    # instead of re-implementing the open-loop arrival logic
    results: Optional[List] = None

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)


def run_staggered(engine, prompts: Sequence[np.ndarray], *, gap_s: float,
                  sampling=None, timeout: float = 600,
                  keep_results: bool = False) -> StaggeredResult:
    """Fire one generation request every ``gap_s`` seconds (open-loop
    arrivals, vs the ladder's closed-loop bursts) and measure per-request
    completion latency — the workload where step-level continuous batching
    beats batch-at-a-time: a request arriving mid-decode joins the
    in-flight batch instead of waiting behind it, and a short-budget row
    retires the step it finishes instead of riding out the batch. Decoder
    engines only (uses the v2 ``generate`` API). ``sampling`` is one
    ``SamplingParams`` for all requests or a per-prompt sequence."""
    t0 = time.perf_counter()
    handles = []
    per_req = (list(sampling) if isinstance(sampling, (list, tuple))
               else [sampling] * len(prompts))
    for i, p in enumerate(prompts):
        handles.append(engine.generate(p, per_req[i]))
        if i + 1 < len(prompts):
            time.sleep(gap_s)
    lats, total_tokens, timings, results = [], 0, [], []
    for h in handles:
        res = h.result(timeout=timeout)
        # per-request completion relative to ITS arrival, not the burst's
        lats.append(res.timing.total_s)
        timings.append(res.timing)
        total_tokens += len(res.tokens)
        results.append(res)
    wall = time.perf_counter() - t0
    return StaggeredResult(
        n_requests=len(prompts), gap_s=gap_s,
        latency_p50_s=float(np.percentile(lats, 50)),
        latency_p95_s=float(np.percentile(lats, 95)),
        wall_s=wall, total_tokens=total_tokens,
        queue_mean_s=float(np.mean([t.queue_s for t in timings])),
        prefill_mean_s=float(np.mean([t.prefill_s for t in timings])),
        decode_mean_s=float(np.mean([t.decode_s for t in timings])),
        queue_p95_s=float(np.percentile([t.queue_s for t in timings], 95)),
        results=results if keep_results else None)


def format_table(cells: List[LoadCell]) -> str:
    lines = ["NS    latency(s)  p95(s)   vCPU%   RAM%"]
    for c in cells:
        lines.append(f"{c.ns:<5d} {c.latency_s:>9.3f} {c.latency_p95_s:>8.3f}"
                     f" {c.vcpu_pct:>7.1f} {c.ram_pct:>6.1f}")
    return "\n".join(lines)
