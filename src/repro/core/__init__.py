"""The paper's primary contribution: the MLaaS deployment study —
GECToR (the deployed model), the cloud-environment matrix, the calibrated
performance/cost models, and the load-test client."""
from repro.core.corpus import CorpusConfig, GECCorpus  # noqa: F401
from repro.core.environments import (INSTANCES, MEASURED, NS_LADDER,  # noqa
                                     instance)
from repro.core.tags import TagVocab, apply_edits, edit_f_beta  # noqa: F401
