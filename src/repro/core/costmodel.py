"""Cost analysis reproducing the paper's Table-5-derived claims, plus
cost-efficiency metrics the paper implies but does not compute
(US$ per million sentences within the 2 s SLO).

Prices come from ``deploy.profiles`` (the single price book); the measured
counterparts of these static numbers are computed by ``deploy.costs`` from
live ``ExperimentRecord`` data and diffed in ``deploy.report``."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.environments import (LATENCY_SLO_S, MACHINES, MEASURED,
                                     NS_LADDER, PROVIDERS, instance)


def gpu_cost_premium() -> Dict[str, float]:
    """Avg GPU (F,G) monthly cost over avg non-GPU (A-E), per provider and
    overall. The paper reports this as '300% more'; the arithmetic from its
    own Table 5 gives ~2.5x — both are recorded (see EXPERIMENTS.md)."""
    out = {}
    ratios = []
    for prov in PROVIDERS:
        cpu = np.mean([instance(prov, m).monthly_cost_usd for m in "ABCDE"])
        gpu = np.mean([instance(prov, m).monthly_cost_usd for m in "FG"])
        out[prov] = gpu / cpu
        ratios.append(gpu / cpu)
    out["overall"] = float(np.mean(ratios))
    return out


def machine_g_vs_f_premium() -> Dict[str, float]:
    """Paper: G costs 43%/35%/43% more than F (AWS/GCP/Azure)."""
    return {prov: instance(prov, "G").monthly_cost_usd
            / instance(prov, "F").monthly_cost_usd - 1.0
            for prov in PROVIDERS}


def machine_c_vs_e_saving() -> Dict[str, float]:
    """Paper: 'cost reduction around 50% for machine C concerning machine E'
    (driven by cache size). True for AWS; per-provider numbers returned."""
    return {prov: 1.0 - instance(prov, "C").monthly_cost_usd
            / instance(prov, "E").monthly_cost_usd
            for prov in PROVIDERS}


def max_ns_within_slo(provider: str, machine: str) -> int:
    """Largest NS whose measured latency meets the 2 s SLO."""
    best = 0
    for ns in NS_LADDER:
        if MEASURED[provider][machine][ns][0] <= LATENCY_SLO_S:
            best = ns
    return best


def cost_per_million_sentences() -> Dict[str, Dict[str, float]]:
    """Beyond-paper metric: US$/1M sentences at each machine's best
    SLO-compliant operating point (NS*/latency(NS*) sentences per second,
    monthly cost spread over a 730 h month)."""
    out: Dict[str, Dict[str, float]] = {}
    for prov in PROVIDERS:
        out[prov] = {}
        for mach in MACHINES:
            ns = max_ns_within_slo(prov, mach)
            if ns == 0:
                out[prov][mach] = float("inf")
                continue
            lat = MEASURED[prov][mach][ns][0]
            sent_per_s = ns / max(lat, 1e-6)
            usd_per_s = instance(prov, mach).hourly_cost_usd / 3600
            out[prov][mach] = usd_per_s / sent_per_s * 1e6
    return out


def cheapest_slo_compliant(target_ns: int = 32) -> Dict[str, str]:
    """Per provider: cheapest machine that meets the SLO at >= target_ns
    concurrent sentences (the paper's POC feasibility question)."""
    out = {}
    for prov in PROVIDERS:
        feasible = [(instance(prov, m).monthly_cost_usd, m)
                    for m in MACHINES
                    if max_ns_within_slo(prov, m) >= target_ns]
        out[prov] = min(feasible)[1] if feasible else None
    return out
