"""The paper's experimental matrix as data.

Table 1 — 21 instance scenarios (3 providers x 7 machine classes A-G).
Table 5 — monthly cost in US$.
Tables 2-4 — the paper's measured (latency s, vCPU %, RAM %) per Number of
Sentences NS in {1,2,...,512}; these are the calibration/validation ground
truth for core.perfsim.

One beyond-paper row is added (TPU_V5E) for the cost comparison the paper
could not run; it is excluded from all paper-claim validations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

NS_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
LATENCY_SLO_S = 2.0                       # the paper's acceptability threshold


@dataclasses.dataclass(frozen=True)
class Instance:
    provider: str
    machine: str                          # class letter A..G
    instance_type: str
    processor: str
    clock_ghz: float
    vcpus: int
    cache_gb: Optional[float]             # None for GPU machines (unlisted)
    ram_gb: int
    gpu: Optional[str]
    monthly_cost_usd: float


INSTANCES = [
    # ---- AWS ----
    Instance("AWS", "A", "c6a.xlarge", "AMD EPYC 7R13", 2.95, 4, 2, 8, None, 110.16),
    Instance("AWS", "B", "c6a.2xlarge", "AMD EPYC 7R13", 2.95, 8, 2, 16, None, 220.32),
    Instance("AWS", "C", "t2.xlarge", "Intel Xeon Scalable", 3.3, 4, 4, 16, None, 133.63),
    Instance("AWS", "D", "inf1.xlarge", "Intel Xeon Platinum 8275CL", 3.0, 4, 2, 8, None, 164.16),
    Instance("AWS", "E", "inf1.2xlarge", "Intel Xeon Platinum 8275CL", 3.0, 8, 2, 16, None, 260.64),
    Instance("AWS", "F", "g4dn.xlarge", "Intel Xeon Platinum 8259CL", 2.5, 4, None, 16, "NVIDIA T4", 378.72),
    Instance("AWS", "G", "g4dn.2xlarge", "Intel Xeon Platinum 8259CL", 2.5, 8, None, 32, "NVIDIA T4", 541.44),
    # ---- GCP ----
    Instance("GCP", "A", "n2d-custom-4-8192", "AMD EPYC Milan 7B13", 3.5, 4, 2, 8, None, 100.44),
    Instance("GCP", "B", "n2d-custom-8-16384", "AMD EPYC Milan 7B13", 3.5, 8, 2, 16, None, 200.87),
    Instance("GCP", "C", "n2-custom-8-16384", "Intel Xeon Gold 6268CL", 3.9, 4, 4, 16, None, 230.89),
    Instance("GCP", "D", "c3-highcpu-4", "Intel Xeon Platinum 8481C", 3.3, 4, 2, 8, None, 124.10),
    Instance("GCP", "E", "c3-highcpu-8", "Intel Xeon Platinum 8481C", 3.3, 8, 2, 16, None, 248.21),
    Instance("GCP", "F", "n1-standard-4", "Intel Xeon Platinum 8173M", 3.5, 4, None, 16, "NVIDIA T4", 388.80),
    Instance("GCP", "G", "n1-standard-8", "Intel Xeon Platinum 8173M", 3.5, 8, None, 32, "NVIDIA T4", 525.60),
    # ---- Azure ----
    Instance("Azure", "A", "standard_B4als_v2", "AMD EPYC Milan 7763v", 3.5, 4, 2, 8, None, 95.76),
    Instance("Azure", "B", "standard_B8als_v2", "AMD EPYC Milan 7763v", 3.5, 8, 2, 16, None, 191.52),
    Instance("Azure", "C", "standard_D8lds_v5", "Intel Xeon Platinum 8370C", 3.5, 4, 4, 16, None, 276.48),
    Instance("Azure", "D", "standard_F4s_v2", "Intel Xeon Platinum 8370C", 3.7, 4, 2, 8, None, 121.68),
    Instance("Azure", "E", "standard_F8s_v2", "Intel Xeon Platinum 8370C", 3.7, 8, 2, 16, None, 243.36),
    Instance("Azure", "F", "standard_NC4as_T4_v3", "AMD EPYC Rome 7V12", 3.3, 4, None, 28, "NVIDIA T4", 383.98),
    Instance("Azure", "G", "standard_NC8as_T4_v3", "AMD EPYC Rome 7V12", 3.3, 8, None, 56, "NVIDIA T4", 548.96),
    # ---- beyond-paper reference point (not part of claim validation) ----
    Instance("TPU", "T", "v5e-1", "TPU v5e (197 TF bf16)", 0.94, 8, None, 16,
             "TPU v5e", 850.0),
]


# (latency_s, vcpu_pct, ram_pct) per provider/machine/NS — Tables 2-4 verbatim.
_T = Tuple[float, float, float]
MEASURED: Dict[str, Dict[str, Dict[int, _T]]] = {
    "AWS": {
        "A": {1: (1.5, 1.5, 84), 2: (0.7, 2.4, 84), 4: (1.3, 3.9, 84),
              8: (2.7, 12.5, 83), 16: (6.5, 38.4, 82), 32: (9.2, 71.8, 82),
              64: (22.1, 99.1, 84), 128: (43.2, 100, 85),
              256: (55.1, 100, 86), 512: (58.1, 100, 86)},
        "B": {1: (0.5, 8.1, 63), 2: (0.3, 1.0, 63), 4: (0.7, 4.0, 62),
              8: (0.9, 6.4, 62), 16: (1.8, 17.5, 59), 32: (2.7, 33, 56),
              64: (4.8, 59.4, 54), 128: (9.7, 77.8, 55),
              256: (17.9, 88.5, 55), 512: (29.5, 83.7, 56)},
        "C": {1: (0.5, 0.5, 60), 2: (0.3, 1.4, 60), 4: (0.4, 2.1, 59),
              8: (0.6, 4.5, 58), 16: (1.2, 17.5, 56), 32: (1.8, 26, 53),
              64: (3.6, 42.6, 52), 128: (6.9, 62.7, 52),
              256: (13, 85.6, 53), 512: (23.3, 78.9, 54)},
        "D": {1: (1.4, 5.1, 86), 2: (0.5, 6.4, 86), 4: (0.6, 7.1, 85),
              8: (0.9, 6.5, 85), 16: (2.2, 12.5, 84), 32: (3.7, 28.1, 83),
              64: (7.9, 71.4, 84), 128: (14.6, 95.4, 85),
              256: (29.5, 99, 86), 512: (42.2, 99.9, 87)},
        "E": {1: (0.8, 0.8, 65), 2: (0.2, 0.5, 64), 4: (0.5, 0.9, 64),
              8: (0.8, 2.5, 63), 16: (1.6, 6.8, 61), 32: (2.4, 15.5, 59),
              64: (4.1, 36.5, 56), 128: (7.9, 62.6, 55),
              256: (14.9, 91.2, 55), 512: (24.3, 90.3, 55)},
        "F": {1: (1.2, 8, 87), 2: (0.4, 2.3, 86), 4: (0.2, 2.1, 86),
              8: (0.2, 3.2, 86), 16: (0.2, 3.8, 86), 32: (0.3, 3.8, 86),
              64: (0.5, 5, 86), 128: (0.9, 7.1, 86), 256: (1.6, 14.3, 86),
              512: (2.9, 34, 86)},
        "G": {1: (0.3, 0.2, 69), 2: (0.03, 0.3, 69), 4: (0.1, 0.4, 69),
              8: (0.1, 0.5, 69), 16: (0.1, 0.8, 69), 32: (0.2, 0.9, 69),
              64: (0.4, 2.1, 69), 128: (0.7, 3.9, 69), 256: (1.2, 14.4, 69),
              512: (2.5, 30.1, 69)},
    },
    "GCP": {
        "A": {1: (1.6, 0.7, 66), 2: (1.3, 3.6, 66), 4: (1.3, 6.7, 66),
              8: (3.0, 20.1, 66), 16: (6.9, 49.2, 67), 32: (12.9, 81.9, 69),
              64: (25.7, 99.2, 71), 128: (43.2, 100, 72),
              256: (55.3, 100, 73), 512: (62.3, 100, 73)},
        "B": {1: (0.3, 0.3, 47), 2: (0.3, 0.7, 47), 4: (1.0, 1.7, 47),
              8: (1.1, 7.2, 47), 16: (1.8, 12.2, 47), 32: (2.6, 25.3, 47),
              64: (5.0, 48.9, 48), 128: (9.9, 75.4, 49),
              256: (18.6, 93.8, 50), 512: (39.5, 91.9, 50)},
        "C": {1: (0.3, 0.4, 47), 2: (0.3, 0.9, 47), 4: (1.0, 1.6, 47),
              8: (1.1, 6.6, 48), 16: (1.8, 11, 48), 32: (2.6, 28.1, 48),
              64: (5.0, 56.1, 49), 128: (9.9, 80.1, 49),
              256: (18.6, 99.1, 50), 512: (39.5, 100, 50)},
        "D": {1: (1.2, 0.6, 65), 2: (1.1, 2.7, 66), 4: (0.7, 5.7, 66),
              8: (1.1, 8, 66), 16: (2.5, 19.6, 67), 32: (4.6, 37.4, 68),
              64: (8.3, 71.9, 69), 128: (16.8, 99.6, 70),
              256: (33.2, 100, 71), 512: (48.1, 100, 72)},
        "E": {1: (1.2, 0.2, 48), 2: (1.1, 0.5, 48), 4: (0.7, 0.9, 48),
              8: (1.1, 4.2, 48), 16: (2.5, 9.6, 48), 32: (4.6, 17.9, 48),
              64: (8.3, 35.5, 49), 128: (16.8, 59.9, 49),
              256: (33.2, 83.4, 50), 512: (48.1, 93.3, 51)},
        "F": {1: (1.3, 1.8, 94), 2: (0.8, 2.7, 94), 4: (0.5, 4.2, 94),
              8: (0.2, 5.7, 94), 16: (0.3, 6.7, 94), 32: (0.4, 7.4, 94),
              64: (0.8, 8.7, 94), 128: (1.4, 12.8, 94), 256: (2.4, 25.5, 94),
              512: (4.3, 54.5, 94)},
        "G": {1: (0.2, 0.4, 76), 2: (0.1, 0.5, 76), 4: (0.1, 0.6, 76),
              8: (0.2, 0.9, 76), 16: (0.3, 1.3, 76), 32: (0.4, 2.3, 76),
              64: (0.6, 5.2, 76), 128: (1.0, 6.9, 76), 256: (1.7, 17.3, 76),
              512: (2.9, 29.6, 76)},
    },
    "Azure": {
        "A": {1: (0.8, 0.5, 67), 2: (0.9, 2.9, 67), 4: (1.2, 5.8, 67),
              8: (3.0, 19.9, 68), 16: (7.1, 55.5, 69), 32: (12.2, 81, 70),
              64: (23.2, 98.1, 72), 128: (42.5, 100, 73),
              256: (54.5, 100, 74), 512: (59.1, 100, 75)},
        "B": {1: (0.2, 0.3, 49), 2: (0.3, 0.6, 49), 4: (1.5, 2.2, 49),
              8: (1.2, 11, 49), 16: (1.7, 17, 49), 32: (2.6, 29.7, 50),
              64: (4.8, 51.8, 50), 128: (9.4, 74.6, 51),
              256: (17.9, 92.1, 52), 512: (39.2, 89.8, 52)},
        "C": {1: (0.1, 0.5, 50), 2: (0.3, 0.7, 50), 4: (0.5, 1.6, 50),
              8: (0.8, 4.4, 50), 16: (1.6, 9.6, 51), 32: (2.6, 22.4, 51),
              64: (5.0, 52.4, 53), 128: (9.8, 78.1, 54),
              256: (18.6, 98.8, 55), 512: (38.6, 99.5, 56)},
        "D": {1: (0.8, 0.8, 74), 2: (0.7, 1.6, 74), 4: (0.7, 4.5, 74),
              8: (1.4, 8.6, 75), 16: (2.7, 21.7, 76), 32: (5.3, 46, 78),
              64: (9.6, 72.7, 80), 128: (20, 95.9, 81),
              256: (37.8, 100, 82), 512: (52.2, 100, 83)},
        "E": {1: (0.2, 0.4, 48), 2: (0.2, 0.6, 48), 4: (0.7, 1.4, 48),
              8: (1.1, 4.8, 48), 16: (1.7, 10.5, 48), 32: (2.6, 22.3, 49),
              64: (4.9, 46.9, 51), 128: (9.6, 75.8, 52),
              256: (18.2, 98.6, 53), 512: (36.7, 98, 54)},
        "F": {1: (0.2, 0.8, 82), 2: (0.1, 0.9, 82), 4: (0.1, 1.0, 82),
              8: (0.1, 1.3, 82), 16: (0.2, 1.8, 82), 32: (0.3, 2.8, 82),
              64: (0.5, 5.4, 82), 128: (0.8, 8.6, 82), 256: (1.5, 16.7, 82),
              512: (2.7, 34.9, 82)},
        "G": {1: (0.1, 0.5, 41), 2: (0.1, 0.5, 41), 4: (0.1, 0.5, 41),
              8: (0.1, 0.6, 41), 16: (0.2, 0.9, 41), 32: (0.3, 1.3, 41),
              64: (0.5, 2.7, 41), 128: (0.8, 5.5, 41), 256: (1.4, 10.7, 41),
              512: (2.5, 24.9, 41)},
    },
}

PROVIDERS = ("AWS", "GCP", "Azure")
MACHINES = tuple("ABCDEFG")


def instance(provider: str, machine: str) -> Instance:
    for inst in INSTANCES:
        if inst.provider == provider and inst.machine == machine:
            return inst
    raise KeyError((provider, machine))


def latency(provider: str, machine: str, ns: int) -> float:
    return MEASURED[provider][machine][ns][0]


def vcpu_load(provider: str, machine: str, ns: int) -> float:
    return MEASURED[provider][machine][ns][1]


def ram_load(provider: str, machine: str, ns: int) -> float:
    return MEASURED[provider][machine][ns][2]
