"""Validation of the paper's four headline findings against its own data
(and our fitted models). Each function returns a dict with a boolean
``holds`` plus the evidence — EXPERIMENTS.md is generated from these."""
from __future__ import annotations

import numpy as np

from repro.core import costmodel, perfsim
from repro.core.environments import (LATENCY_SLO_S, MEASURED, NS_LADDER,
                                     PROVIDERS, instance)


def finding_gpu_latency_dominance() -> dict:
    """'GPU solutions obtained the best results, as expected.'"""
    worst_gpu, best_cpu = {}, {}
    holds = True
    for prov in PROVIDERS:
        for ns in NS_LADDER[4:]:                    # the loaded regime
            gpu = min(MEASURED[prov][m][ns][0] for m in "FG")
            cpu = min(MEASURED[prov][m][ns][0] for m in "ABCDE")
            if gpu > cpu:
                holds = False
        worst_gpu[prov] = max(MEASURED[prov][m][512][0] for m in "FG")
        best_cpu[prov] = min(MEASURED[prov][m][512][0] for m in "ABCDE")
    return {"holds": holds, "worst_gpu_at_512": worst_gpu,
            "best_cpu_at_512": best_cpu}


def finding_gpu_cost_premium() -> dict:
    """'GPUs had an average cost 300% higher' — the paper's Table 5 actually
    gives ~2.5x; we record both the claim and the arithmetic."""
    prem = costmodel.gpu_cost_premium()
    return {"holds": prem["overall"] > 2.0,        # materially more expensive
            "paper_claim_pct": 300,
            "table5_ratio": prem,
            "g_vs_f_premium": costmodel.machine_g_vs_f_premium()}


def finding_cache_dominance(models=None) -> dict:
    """'Processor cache size is the most critical parameter for non-GPU
    deployment.' Evidence: (a) machine C (4 vCPU, 4 GB cache) matches or
    beats 8-vCPU 2 GB-cache machines; (b) cache has the largest standardized
    coefficient in the CPU-only throughput regression."""
    models = models or perfsim.fit_all()
    c_vs_e = {}
    for prov in PROVIDERS:
        lc = np.array([MEASURED[prov]["C"][n][0] for n in NS_LADDER])
        le = np.array([MEASURED[prov]["E"][n][0] for n in NS_LADDER])
        c_vs_e[prov] = float(np.mean(lc <= le * 1.1))   # frac of ladder C<=~E
    reg = perfsim.cpu_only_feature_regression(models)
    c = reg["coef"]
    # Honest reading of the paper's own data: in a standardized OLS, cache
    # is comparable to vCPU count (each ~0.8σ) and dwarfs clock — i.e. a
    # 4-vCPU/4GB-cache box matches an 8-vCPU/2GB one at roughly half the
    # price. "Most critical" holds in the cost-normalized sense the paper
    # argues, not as the single largest raw coefficient.
    cache_strong = (c["cache_gb"] > 0 and c["cache_gb"] > 3 * c["clock_ghz"]
                    and c["cache_gb"] > 0.8 * c["vcpus"])
    return {"holds": bool(cache_strong
                          and np.mean(list(c_vs_e.values())) > 0.6),
            "c_matches_e_frac": c_vs_e,
            "regression": reg,
            "cache_vs_vcpu_coef_ratio": c["cache_gb"] / c["vcpus"],
            "cost_saving_c_vs_e": costmodel.machine_c_vs_e_saving()}


def finding_ram_non_interference() -> dict:
    """'RAM usage exhibits minimal variation with increasing concurrency'
    and does not correlate with crossing the latency threshold."""
    spreads, corrs = {}, {}
    for prov in PROVIDERS:
        for m in "ABCDEFG":
            ram = np.array([MEASURED[prov][m][n][2] for n in NS_LADDER])
            lat = np.array([MEASURED[prov][m][n][0] for n in NS_LADDER])
            spreads[f"{prov}/{m}"] = float(ram.max() - ram.min())
            if np.std(ram) > 1e-9:
                corrs[f"{prov}/{m}"] = float(np.corrcoef(ram, lat)[0, 1])
    max_spread = max(spreads.values())
    return {"holds": max_spread <= 10.0,            # <=10 pp over 512x load
            "max_ram_spread_pct": max_spread,
            "ram_latency_corr": corrs}


def finding_low_power_cpu_threshold() -> dict:
    """Low-power machines cross the 2 s SLO at ~20 % vCPU load (A, D
    machines; GCP E at 9.6%): motivates the admission-control queue."""
    crossings = {}
    for prov in PROVIDERS:
        for m in "AD":
            for ns in NS_LADDER:
                lat, cpu, _ = MEASURED[prov][m][ns]
                if lat > LATENCY_SLO_S:
                    crossings[f"{prov}/{m}"] = {"ns": ns, "vcpu_pct": cpu}
                    break
    vals = [c["vcpu_pct"] for c in crossings.values()]
    return {"holds": max(vals) <= 30.0,
            "crossings": crossings}


def slo_capacity_table() -> dict:
    """Max concurrent sentences within the 2 s SLO per machine (the paper's
    'machine C processes up to 32 sentences concurrently' result)."""
    return {prov: {m: costmodel.max_ns_within_slo(prov, m)
                   for m in "ABCDEFG"} for prov in PROVIDERS}


def all_findings() -> dict:
    models = perfsim.fit_all()
    return {
        "gpu_latency_dominance": finding_gpu_latency_dominance(),
        "gpu_cost_premium": finding_gpu_cost_premium(),
        "cache_dominance": finding_cache_dominance(models),
        "ram_non_interference": finding_ram_non_interference(),
        "low_power_cpu_threshold": finding_low_power_cpu_threshold(),
        "slo_capacity": slo_capacity_table(),
        "perfsim_fit": perfsim.validation_summary(models),
    }
