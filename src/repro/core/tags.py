"""GECToR edit-tag vocabulary ("Tag, Not Rewrite").

Tags per source token: KEEP, DELETE, APPEND_w (insert w after this token),
REPLACE_w (substitute this token with w), with w drawn from the K most
frequent words. This is the paper-faithful reduction of GECToR's 5000-tag
vocabulary (g-transforms like CASE/AGREEMENT are lexical in our synthetic
setting, so APPEND/REPLACE cover them).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

KEEP = 0
DELETE = 1


class TagVocab:
    def __init__(self, n_words: int, token_offset: int = 0):
        """``token_offset``: token id of edit-word 0 (the corpus reserves
        low ids for specials, so its editable words are ids [2, 2+K))."""
        self.n_words = n_words
        self.token_offset = token_offset
        self.n_tags = 2 + 2 * n_words

    def append(self, token: int) -> int:
        w = token - self.token_offset
        assert 0 <= w < self.n_words
        return 2 + w

    def replace(self, token: int) -> int:
        w = token - self.token_offset
        assert 0 <= w < self.n_words
        return 2 + self.n_words + w

    def describe(self, tag: int) -> str:
        if tag == KEEP:
            return "KEEP"
        if tag == DELETE:
            return "DELETE"
        if tag < 2 + self.n_words:
            return f"APPEND_{tag - 2}"
        return f"REPLACE_{tag - 2 - self.n_words}"

    def is_append(self, tag) -> bool:
        return 2 <= tag < 2 + self.n_words

    def is_replace(self, tag) -> bool:
        return tag >= 2 + self.n_words

    def word_of(self, tag: int) -> int:
        """Token id of the word carried by an APPEND/REPLACE tag."""
        if self.is_append(tag):
            return tag - 2 + self.token_offset
        if self.is_replace(tag):
            return tag - 2 - self.n_words + self.token_offset
        raise ValueError(tag)


def apply_edits(vocab: TagVocab, tokens: Sequence[int],
                tags: Sequence[int]) -> List[int]:
    """Apply one round of predicted edits to a token sequence."""
    out: List[int] = []
    for tok, tag in zip(tokens, tags):
        if tag == DELETE:
            continue
        if vocab.is_replace(tag):
            out.append(vocab.word_of(tag))
            continue
        out.append(int(tok))
        if vocab.is_append(tag):
            out.append(vocab.word_of(tag))
    return out


def edit_f_beta(pred_tags: np.ndarray, gold_tags: np.ndarray,
                mask: np.ndarray, beta: float = 0.5) -> dict:
    """Tag-level F_beta over non-KEEP edits (the GEC convention: precision-
    weighted F0.5, as in the paper's 65.3% CoNLL-2014 reference)."""
    pred_e = (pred_tags != KEEP) & mask
    gold_e = (gold_tags != KEEP) & mask
    tp = int(np.sum(pred_e & gold_e & (pred_tags == gold_tags)))
    fp = int(np.sum(pred_e)) - tp
    fn = int(np.sum(gold_e & ~(pred_e & (pred_tags == gold_tags))))
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    b2 = beta * beta
    f = ((1 + b2) * prec * rec / max(b2 * prec + rec, 1e-9)
         if (prec + rec) else 0.0)
    return {"precision": prec, "recall": rec, f"f{beta}": f,
            "tp": tp, "fp": fp, "fn": fn}
