"""Lock-discipline pass (``locks``): ``# guarded-by:`` enforcement.

The serving engine is three threads (submitters, the worker, measuring
clients) sharing one object graph. Which lock protects which field was
tribal knowledge; this pass makes it a checked annotation. A field is
declared where it is initialised:

    self._overflow = deque()       # guarded-by: _submit_lock
    self._stats = Stats()          # guarded-by: worker
    self._q = queue.Queue()        # guarded-by: threadsafe
    self.cfg = cfg                 # guarded-by: init
    self._heap = []                # guarded-by: external
    self._win_cursor = 0           # guarded-by: client

Guard kinds:

``<lockname>``  access only inside ``with <obj>.<lockname>:`` or in a
                function whose ``def`` line carries ``# holds:
                <lockname>`` (for callers that take the lock upstream).
``worker``      owned by the single worker thread; access only in
                functions marked ``# holds: worker``.
``threadsafe``  internally synchronized (queue.Queue, Event, locks
                themselves) — reads/writes are free.
``init``        written once in ``__init__``; later *stores* are
                flagged, reads are free anywhere.
``external``    internal to the declaring class, callers must hold
                whatever lock guards the *instance* — any touch from
                another class is flagged.
``client``      owned by the measuring client between runs; unenforced
                (single-threaded by protocol).

Enforcement is name-based and scoped to modules that declare at least
one annotation (the four serving modules), so an unrelated ``self.lanes``
elsewhere in the repo is not dragged in. Accesses inside the declaring
class's ``__init__`` are exempt (construction happens-before sharing).
Nested defs inherit the enclosing function's ``# holds:`` markers but
not its ``with`` locks (a closure may run after the block exits).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, Module, register, terminal_name)

_GUARD_RE = re.compile(r"guarded-by:\s*([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"holds:\s*([A-Za-z_]\w*)")
_KINDS = {"worker", "threadsafe", "init", "external", "client"}


class _Decl(NamedTuple):
    field: str
    guard: str          # a kind from _KINDS, or a lock attribute name
    cls: str            # declaring class name
    rel: str            # declaring module


def _collect_decls(modules: Sequence[Module]) -> Dict[str, List[_Decl]]:
    decls: Dict[str, List[_Decl]] = {}
    for mod in modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for item in node.body:
                _scan_stmts(mod, node.name, [item], decls, class_body=True)
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and item.name == "__init__":
                    _scan_stmts(mod, node.name, ast.walk(item), decls)
    return decls


def _scan_stmts(mod, cls, stmts, decls, class_body=False):
    for stmt in stmts:
        if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            continue
        m = _GUARD_RE.search(mod.comment_at(stmt.lineno))
        if not m:
            continue
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for tgt in targets:
            field = None
            if isinstance(tgt, ast.Attribute) \
                    and isinstance(tgt.value, ast.Name) \
                    and tgt.value.id == "self":
                field = tgt.attr
            elif class_body and isinstance(tgt, ast.Name):
                field = tgt.id
            if field:
                decls.setdefault(field, []).append(
                    _Decl(field, m.group(1), cls, mod.rel))


@register
class LocksPass:
    name = "locks"
    description = ("`# guarded-by:` discipline: annotated shared fields "
                   "accessed outside their lock / owning thread")

    def run(self, modules: Sequence[Module]) -> List[Finding]:
        decls = _collect_decls(modules)
        findings: List[Finding] = []
        if not decls:
            return findings
        for mod in modules:
            if not any(d.rel == mod.rel for ds in decls.values()
                       for d in ds):
                continue   # enforcement is opt-in per module
            findings.extend(self._check_module(mod, decls))
        return findings

    def _check_module(self, mod: Module, decls) -> List[Finding]:
        findings: List[Finding] = []
        seen: Set[Tuple[int, int, str]] = set()

        def fn_holds(fn) -> Set[str]:
            out = set()
            for line in range(fn.lineno,
                              fn.body[0].lineno if fn.body else fn.lineno):
                out.update(_HOLDS_RE.findall(mod.comment_at(line)))
            return out

        def check_access(node: ast.Attribute, cls, qual, held, holds,
                         in_declaring_init):
            ds = decls.get(node.attr)
            if not ds:
                return
            is_store = not isinstance(node.ctx, ast.Load)
            ok = False
            for d in ds:
                if d.cls == cls and in_declaring_init:
                    ok = True
                elif d.guard == "threadsafe" or d.guard == "client":
                    ok = True
                elif d.guard == "worker":
                    ok = "worker" in holds
                elif d.guard == "init":
                    ok = not is_store
                elif d.guard == "external":
                    ok = d.cls == cls
                else:                      # a lock attribute name
                    ok = d.guard in held or d.guard in holds
                if ok:
                    return
            d = ds[0]
            key = (node.lineno, node.col_offset, node.attr)
            if key in seen:
                return
            seen.add(key)
            what = "written" if is_store else "read"
            if d.guard == "worker":
                msg = (f"`.{node.attr}` is worker-thread state "
                       f"(guarded-by: worker) but is {what} in "
                       f"`{qual}`, which is not marked `# holds: worker`")
                hint = ("mark the function `# holds: worker` if it only "
                        "runs on the worker thread, or route through the "
                        "request queue")
            elif d.guard == "init":
                msg = (f"`.{node.attr}` is init-only (guarded-by: init) "
                       f"but is re-assigned in `{qual}` after "
                       f"construction")
                hint = ("treat the field as immutable; build a new value "
                        "in __init__ or pick a real guard")
            elif d.guard == "external":
                msg = (f"`.{node.attr}` is internal to `{d.cls}` "
                       f"(guarded-by: external) but is {what} from "
                       f"`{qual}`")
                hint = (f"go through `{d.cls}`'s methods and hold the "
                        f"lock that guards the instance")
            else:
                msg = (f"`.{node.attr}` (guarded-by: {d.guard}) is "
                       f"{what} in `{qual}` outside `with "
                       f"...{d.guard}:`")
                hint = (f"wrap the access in `with self.{d.guard}:`, or "
                        f"mark the function `# holds: {d.guard}` if the "
                        f"caller already owns it")
            findings.append(Finding(
                self.name, mod.rel, node.lineno, node.col_offset, qual,
                node.attr, msg, hint))

        def walk(body, cls, qual, held: Set[str], holds: Set[str],
                 in_declaring_init: bool):
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    q = f"{qual}.{stmt.name}" if qual else stmt.name
                    init = stmt.name == "__init__"
                    walk(stmt.body, cls, q, set(),
                         holds | fn_holds(stmt), init)
                elif isinstance(stmt, ast.ClassDef):
                    walk(stmt.body, stmt.name, stmt.name, set(), set(),
                         False)
                elif isinstance(stmt, ast.With):
                    now = set(held)
                    for item in stmt.items:
                        name = terminal_name(item.context_expr)
                        if name:
                            now.add(name)
                        scan_exprs(item.context_expr, cls, qual, held,
                                   holds, in_declaring_init)
                    walk_stmt_children(stmt.body, cls, qual, now, holds,
                                       in_declaring_init)
                else:
                    scan_exprs(stmt, cls, qual, held, holds,
                               in_declaring_init)
                    for attr, blocks in _nested_blocks(stmt):
                        walk(blocks, cls, qual, held, holds,
                             in_declaring_init)

        def walk_stmt_children(body, *ctx):
            walk(body, *ctx)

        def scan_exprs(node, cls, qual, held, holds, in_init):
            """Check every annotated-attribute access in ``node``,
            without descending into nested defs or nested statement
            blocks (those are handled by walk with updated context)."""
            if isinstance(node, ast.Attribute):
                check_access(node, cls, qual, held, holds, in_init)
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef, ast.Lambda, ast.stmt)):
                    continue           # handled by walk / deferred
                scan_exprs(child, cls, qual, held, holds, in_init)

        def _nested_blocks(stmt):
            for field_name in ("body", "orelse", "finalbody"):
                blocks = getattr(stmt, field_name, None)
                if blocks and isinstance(blocks, list) \
                        and blocks and isinstance(blocks[0], ast.stmt):
                    yield field_name, blocks
            for h in getattr(stmt, "handlers", []) or []:
                yield "handler", h.body

        walk(mod.tree.body, None, "<module>", set(), set(), False)
        return findings
