"""Donation-safety pass (``donation``): use-after-donate detection.

``jax.jit(..., donate_argnums=k)`` tells XLA it may reuse argument
``k``'s buffers for the output. On CPU the donated array often survives
by accident; on TPU/GPU it really is gone, so a later read returns
garbage *silently* — no exception, just wrong KV. The CachePool
reset/scatter helpers and the engine's decode segment all donate, so
the idiom must stay mechanically safe:

    pool.caches = _reset_slots(pool.caches, ...)   # ok: rebound at once
    out = _reset_slots(pool.caches, ...)
    use(pool.caches)                               # FLAGGED

The pass resolves donating callables repo-wide, without importing:

  * defs decorated ``@functools.partial(jax.jit, donate_argnums=k)``;
  * ``name = jax.jit(f, donate_argnums=k)`` bindings;
  * factory methods that build a donating jit into a cache and return
    it (the engine's ``self._compiled[...] = jax.jit(fn,
    donate_argnums=k)`` + ``return self._compiled[...]`` pattern) —
    their call shape is ``obj.factory()(args...)``.

At each call site it taints the donated argument when that argument is
a stable dotted binding (``caches``, ``pool.caches``); the taint dies
when the binding (or a prefix of it) is re-assigned, and any read while
tainted is a finding. Loop bodies are walked twice so a donation whose
taint survives to the back edge catches first-statement reads of the
next iteration. Matching is by terminal callable name, which is exact
enough for this repo's single-namespace helpers; a same-named
non-donating function would need a baseline entry, making the
collision loud instead of silent.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.core import (Finding, Module, dotted, iter_functions,
                                 jit_call_info, register, terminal_name)


def _donating_defs(modules: Sequence[Module]):
    """(donors, factories): terminal callable name -> donated argnums."""
    donors: Dict[str, Tuple[int, ...]] = {}
    factories: Dict[str, Tuple[int, ...]] = {}
    for mod in modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    info = jit_call_info(dec) if isinstance(dec, ast.Call) \
                        else None
                    if info and info[1]:
                        donors[node.name] = info[1]
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                info = jit_call_info(node.value)
                if not (info and info[1]):
                    continue
                for tgt in node.targets:
                    name = terminal_name(tgt)
                    if name is not None:
                        donors[name] = info[1]
        # factory methods: a donating jit stored into a subscripted cache
        # inside a function makes calls of the form ``obj.meth()(args)``
        # donate — record the enclosing function's name
        for qual, fn, _cls in iter_functions(mod.tree):
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and \
                        isinstance(node.value, ast.Call) and \
                        any(isinstance(t, ast.Subscript)
                            for t in node.targets):
                    info = jit_call_info(node.value)
                    if info and info[1]:
                        factories[fn.name] = info[1]
    return donors, factories


class _RW(ast.NodeVisitor):
    """Collect maximal dotted paths read (Load ctx) and written
    (Store/Del ctx) by an expression/statement fragment. Nested function
    bodies are skipped — they run later, under bindings that may have
    been refreshed by then."""

    def __init__(self):
        self.loads: List[Tuple[str, int, int]] = []
        self.stores: List[str] = []

    def _path(self, node):
        p = dotted(node)
        if p is None:
            return None
        if isinstance(node.ctx, ast.Load):
            self.loads.append((p, node.lineno, node.col_offset))
        else:
            self.stores.append(p)
        return p

    def visit_Attribute(self, node):
        if self._path(node) is None:
            self.generic_visit(node)

    def visit_Name(self, node):
        self._path(node)

    def visit_FunctionDef(self, node):  # deferred execution
        pass

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef
    visit_ClassDef = visit_FunctionDef


def _rw(node) -> _RW:
    v = _RW()
    v.visit(node)
    return v


@register
class DonationPass:
    name = "donation"
    description = ("use-after-donate: a binding passed as a "
                   "donate_argnums argument is read before being "
                   "re-assigned")

    def run(self, modules: Sequence[Module]) -> List[Finding]:
        donors, factories = _donating_defs(modules)
        findings: List[Finding] = []
        for mod in modules:
            for qual, fn, _cls in iter_functions(mod.tree):
                findings.extend(self._check_function(
                    mod, qual, fn, donors, factories))
        return findings

    # ------------------------------------------------------- one function
    def _check_function(self, mod, qual, fn, donors, factories):
        findings: List[Finding] = []
        seen: Set[Tuple[str, int, int]] = set()
        # taint: donated path -> (callee, donated line)
        taint: Dict[str, Tuple[str, int]] = {}

        def donated_args(call: ast.Call):
            """Paths this call donates, as (path, callee-name) pairs."""
            fname = terminal_name(call.func)
            idxs = donors.get(fname) if fname else None
            if idxs is None and isinstance(call.func, ast.Call):
                inner = terminal_name(call.func.func)
                if inner in factories and not call.func.args:
                    idxs, fname = factories[inner], f"{inner}()"
            if not idxs:
                return []
            out = []
            for i in idxs:
                if i < len(call.args):
                    p = dotted(call.args[i])
                    if p is not None:
                        out.append((p, fname, i))
            return out

        def check_loads(rw: _RW):
            for p, line, col in rw.loads:
                for t, (callee, dline, idx) in taint.items():
                    if p == t or p.startswith(t + "."):
                        key = (p, line, col)
                        if key in seen:
                            continue
                        seen.add(key)
                        findings.append(Finding(
                            self.name, mod.rel, line, col, qual, t,
                            f"`{p}` is read after `{t}` was donated to "
                            f"`{callee}` (donate_argnums includes {idx}) "
                            f"on line {dline}; the donated buffer may "
                            f"alias freed memory",
                            hint="rebind the donated argument from the "
                                 "call's result before reading it, or "
                                 "pass a value you will not reuse"))

        def kill(stores):
            for s in stores:
                for t in list(taint):
                    if t == s or t.startswith(s + "."):
                        del taint[t]

        def handle_stmt(stmt):
            rw = _rw(stmt)
            check_loads(rw)
            new = []
            for call in ast.walk(stmt):
                if isinstance(call, ast.Call):
                    for p, callee, idx in donated_args(call):
                        new.append((p, callee, call.lineno, idx))
            kill(rw.stores)
            for p, callee, line, idx in new:
                if p not in rw.stores and not any(
                        p == s or p.startswith(s + ".")
                        for s in rw.stores):
                    taint[p] = (callee, line, idx)

        def walk_block(body):
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue
                if isinstance(stmt, ast.If):
                    check_loads(_rw(stmt.test))
                    before = dict(taint)
                    walk_block(stmt.body)
                    after_body = dict(taint)
                    taint.clear()
                    taint.update(before)
                    walk_block(stmt.orelse)
                    taint.update(after_body)   # alive on either path: keep
                elif isinstance(stmt, (ast.For, ast.While)):
                    check_loads(_rw(stmt.iter if isinstance(stmt, ast.For)
                                    else stmt.test))
                    if isinstance(stmt, ast.For):
                        kill(_rw(stmt.target).stores)
                    walk_block(stmt.body)
                    # back edge: taints alive at the loop end reach the
                    # top of the next iteration — walk the body again
                    # (findings de-dupe on position)
                    walk_block(stmt.body)
                    walk_block(stmt.orelse)
                elif isinstance(stmt, ast.With):
                    for item in stmt.items:
                        check_loads(_rw(item.context_expr))
                        if item.optional_vars is not None:
                            kill(_rw(item.optional_vars).stores)
                    walk_block(stmt.body)
                elif isinstance(stmt, ast.Try):
                    walk_block(stmt.body)
                    for h in stmt.handlers:
                        walk_block(h.body)
                    walk_block(stmt.orelse)
                    walk_block(stmt.finalbody)
                else:
                    handle_stmt(stmt)

        walk_block(fn.body)
        return findings
