"""Recompile-hazard pass (``recompile``): jit cache-defeat patterns.

``jax.jit`` caches compiled programs *on the wrapper object*. Build the
wrapper in the wrong place and the cache is thrown away while the code
still "works" — each call silently pays a full XLA compile (seconds)
where the steady state should pay microseconds. The engine's
``jit_compiles`` counter catches this at runtime, *after* it has cost a
measured window; this pass catches it at review time:

  * inline construction at the call site —
    ``jax.jit(f, ...)(args)`` builds wrapper + empty cache per call
    (the original ``core/gector.py`` bug); ``jax.jit(...).lower(...)``
    is exempt, that is the deliberate AOT idiom;
  * ``jax.jit`` constructed inside a ``for``/``while`` body — one
    fresh cache per iteration;
  * static-arg mismatches against a resolvable target def:
    ``static_argnums`` out of range, ``static_argnames`` naming a
    parameter that does not exist (jit raises only on first call), and
    list/dict/set literals passed in a static position (unhashable →
    ``TypeError`` at call time);
  * jitted functions closing over *rebound* module globals — a global
    that is assigned more than once at module scope or via ``global``
    inside a function is baked in at trace time, so later rebinds are
    silently ignored. Constant module globals are fine and not flagged.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, Module, iter_functions,
                                 jit_call_info, register, terminal_name)


def _parents(tree) -> Dict[ast.AST, ast.AST]:
    out: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            out[child] = node
    return out


def _positional_params(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _rebound_globals(tree) -> Set[str]:
    """Module-level names assigned more than once, or rebound through a
    ``global`` declaration inside a function."""
    counts: Dict[str, int] = {}
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            targets = [stmt.target]
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    counts[n.id] = counts.get(n.id, 0) + 1
    rebound = {n for n, c in counts.items() if c > 1}
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            rebound.update(n for n in node.names if n in counts)
    return rebound


def _local_names(fn) -> Set[str]:
    names: Set[str] = set(_positional_params(fn))
    names.update(p.arg for p in fn.args.kwonlyargs)
    if fn.args.vararg:
        names.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        names.add(fn.args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and not isinstance(node.ctx, ast.Load):
            names.add(node.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            names.add(node.name)
    return names


@register
class RecompilePass:
    name = "recompile"
    description = ("jit cache-defeat: inline jax.jit at call sites, jit "
                   "in loops, static-arg mismatches, closures over "
                   "rebound module globals")

    def run(self, modules: Sequence[Module]) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            findings.extend(self._check_module(mod))
        return findings

    def _check_module(self, mod: Module) -> List[Finding]:
        findings: List[Finding] = []
        parents = _parents(mod.tree)
        quals = {fn: q for q, fn, _c in iter_functions(mod.tree)}
        defs_by_name: Dict[str, ast.AST] = {}
        for q, fn, _c in iter_functions(mod.tree):
            defs_by_name.setdefault(fn.name, fn)

        def qual_of(node) -> str:
            cur = parents.get(node)
            while cur is not None:
                if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    return quals.get(cur, cur.name)
                cur = parents.get(cur)
            return "<module>"

        def flag(node, detail, message, hint):
            findings.append(Finding(
                self.name, mod.rel, node.lineno, node.col_offset,
                qual_of(node), detail, message, hint))

        #: jit-wrapped bindings with literal static_argnums, for the
        #: unhashable-static call-site check: name -> static indices
        static_bindings: Dict[str, Tuple[int, ...]] = {}

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            info = jit_call_info(node)
            if info is None:
                continue
            target, _donate, static_nums, static_names = info
            tname = terminal_name(target) if target is not None else None
            detail = tname or "jax.jit"

            parent = parents.get(node)
            if isinstance(parent, ast.Call) and parent.func is node:
                # jax.jit(...)(args) — the gector.py:75 shape.
                # (jax.jit(...).lower(...) has an Attribute parent and
                # is the sanctioned AOT path.)
                flag(parent, detail,
                     f"inline `jax.jit({detail or '...'})` called "
                     f"directly at the call site: a fresh wrapper — and "
                     f"an empty compile cache — is built on every call",
                     hint="hoist the jit to a module-level (or cached) "
                          "binding so compiled programs are reused")

            cur = parent
            while cur is not None and not isinstance(
                    cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda, ast.Module)):
                if isinstance(cur, (ast.For, ast.While)):
                    flag(node, detail,
                         f"`jax.jit` constructed inside a "
                         f"`{'for' if isinstance(cur, ast.For) else 'while'}"
                         f"` loop: each iteration builds a new wrapper "
                         f"and recompiles from scratch",
                         hint="hoist the jit construction above the loop")
                    break
                cur = parents.get(cur)

            # static-arg validation against a same-module target def
            target_def = defs_by_name.get(tname) if tname else None
            if target_def is not None:
                params = _positional_params(target_def)
                all_params = set(params) | {p.arg for p in
                                            target_def.args.kwonlyargs}
                for i in static_nums or ():
                    if not (0 <= i < len(params)):
                        flag(node, detail,
                             f"static_argnums includes {i} but "
                             f"`{tname}` has only {len(params)} "
                             f"positional parameter(s) — jit raises on "
                             f"first call",
                             hint="fix the index (or use static_argnames)")
                for s in static_names or ():
                    if s not in all_params:
                        flag(node, detail,
                             f"static_argnames includes '{s}' which is "
                             f"not a parameter of `{tname}` — jit "
                             f"raises on first call",
                             hint="match static_argnames to the "
                                  "target's signature")

            if static_nums:
                assign = parents.get(node)
                if isinstance(assign, ast.Assign):
                    for t in assign.targets:
                        n = terminal_name(t)
                        if n:
                            static_bindings[n] = static_nums

        # unhashable literals in static positions at call sites
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = terminal_name(node.func)
            idxs = static_bindings.get(fname) if fname else None
            for i in idxs or ():
                if i < len(node.args) and isinstance(
                        node.args[i], (ast.List, ast.Dict, ast.Set)):
                    flag(node.args[i], fname,
                         f"mutable literal passed in static position "
                         f"{i} of jitted `{fname}`: statics must be "
                         f"hashable (TypeError at call time) and every "
                         f"distinct value recompiles",
                         hint="pass a tuple / frozen value, or make the "
                              "argument traced")

        # jitted closures over rebound module globals
        rebound = _rebound_globals(mod.tree)
        if rebound:
            jitted: Set[ast.AST] = set()
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        if (isinstance(dec, ast.Call)
                                and jit_call_info(dec)) or \
                                terminal_name(dec) == "jit":
                            jitted.add(node)
                elif isinstance(node, ast.Call):
                    info = jit_call_info(node)
                    if info and isinstance(info[0], ast.Name) \
                            and info[0].id in defs_by_name:
                        jitted.add(defs_by_name[info[0].id])
            for fn in jitted:
                local = _local_names(fn)
                reported: Set[str] = set()
                for node in ast.walk(fn):
                    if isinstance(node, ast.Name) \
                            and isinstance(node.ctx, ast.Load) \
                            and node.id in rebound \
                            and node.id not in local \
                            and node.id not in reported:
                        reported.add(node.id)
                        flag(node, node.id,
                             f"jitted `{fn.name}` closes over module "
                             f"global `{node.id}`, which is rebound "
                             f"elsewhere: the traced value is baked in "
                             f"at first call and later rebinds are "
                             f"silently ignored",
                             hint="pass the value as an argument (traced "
                                  "or static) instead of reading a "
                                  "mutable global")
        return findings
