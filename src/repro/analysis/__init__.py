"""repro-lint: JAX-aware static analysis over the serving hot path.

The paper's cost finding only holds while the hot path stays tight —
no stray recompiles, no host syncs inside traced code, no use of a
buffer after jit donated it, no unlocked touch of cross-thread state.
Each of those is a *silent* failure mode: the engine keeps producing
tokens while a measured window quietly pays a compile, or a donated
cache is read as garbage only on hardware where donation actually
aliases. This package turns the per-PR vigilance into four AST passes
(pure stdlib — no jax import, so CI can run them without an
accelerator stack):

  donation   use-after-donate on ``donate_argnums`` call sites
  trace      host syncs / Python control flow on traced values inside
             jit-reachable functions
  locks      ``# guarded-by:`` discipline for the threaded serving
             modules
  recompile  inline ``jax.jit`` at call sites, static-arg mismatches,
             jitted closures over mutable module state

``tools/lint.py`` is the CLI; ``docs/ANALYSIS.md`` the catalog and the
annotation / baseline workflow.
"""
from repro.analysis.core import (Baseline, BaselineEntry, Finding, Module,
                                 PASSES, load_modules, register, run_passes)
from repro.analysis import donation, locks, recompile, trace_safety  # noqa: F401 — register passes

__all__ = ["Baseline", "BaselineEntry", "Finding", "Module", "PASSES",
           "load_modules", "register", "run_passes"]
