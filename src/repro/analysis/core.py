"""Shared core for the repro-lint passes: parsed-module model, pass
registry, finding shape, and the baseline (suppression) file.

Everything here is pure stdlib (``ast`` + ``tokenize``) on purpose: the
CI lint job and the tier-1 meta-test run the whole suite without jax
installed. Passes never *import* the code they analyse — fixture files
are free to reference a fake ``jax`` and broken code parses fine.

A pass is a class with ``name``/``description`` and a
``run(modules) -> [Finding]`` method, registered via ``@register`` so
``tools/lint.py`` and the tests discover it from one place. Adding a
pass = one module with one registered class plus fixtures
(docs/ANALYSIS.md walks through it).
"""
from __future__ import annotations

import ast
import fnmatch
import io
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

#: pass name -> pass class; filled by @register at import time
PASSES: Dict[str, type] = {}


def register(cls):
    """Class decorator adding a pass to the global registry."""
    PASSES[cls.name] = cls
    return cls


@dataclass(frozen=True)
class Finding:
    """One problem a pass found.

    ``qualname`` is the enclosing function/class path (``Cls.meth`` or
    ``<module>``), ``detail`` the stable symbol the finding is about
    (the donated path, the annotated field, the jitted name ...) —
    together with ``pass_id`` and ``path`` they form the baseline key,
    so suppressions survive line-number churn. ``hint`` is the fix
    suggestion printed after the message.
    """
    pass_id: str
    path: str            # repo-relative posix path
    line: int
    col: int
    qualname: str
    detail: str
    message: str
    hint: str = ""

    def render(self) -> str:
        s = (f"{self.path}:{self.line}:{self.col}: [{self.pass_id}] "
             f"{self.message}")
        if self.hint:
            s += f"  (fix: {self.hint})"
        return s

    def sort_key(self):
        return (self.path, self.line, self.col, self.pass_id, self.detail)


class Module:
    """One parsed source file: AST + per-line comment map.

    Comments come from ``tokenize`` (not regex over lines), so a ``#``
    inside a string literal never reads as an annotation. Files that
    fail to tokenize still get an AST-only view.
    """

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=rel)
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:      # pragma: no cover - defensive
            pass

    def comment_at(self, line: int) -> str:
        """Comment text on ``line`` (trailing or standalone), '' if none."""
        return self.comments.get(line, "")


def load_modules(root: Path, paths: Optional[Sequence[Path]] = None
                 ) -> List[Module]:
    """Parse every ``.py`` under ``root/src`` (or the explicit ``paths``)
    into ``Module``s with repo-relative names."""
    root = Path(root)
    if paths is None:
        paths = sorted((root / "src").rglob("*.py"))
    mods = []
    for p in paths:
        p = Path(p)
        try:
            rel = p.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = p.name
        mods.append(Module(p, rel, p.read_text()))
    return mods


def run_passes(modules: Sequence[Module],
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the selected (default: all registered) passes and return
    findings in stable (path, line) order."""
    names = list(select) if select else sorted(PASSES)
    unknown = [n for n in names if n not in PASSES]
    if unknown:
        raise ValueError(f"unknown pass(es): {', '.join(unknown)} "
                         f"(registered: {', '.join(sorted(PASSES))})")
    findings: List[Finding] = []
    for name in names:
        findings.extend(PASSES[name]().run(modules))
    return sorted(findings, key=Finding.sort_key)


# ---------------------------------------------------------------- AST utils
def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, None for anything else
    (calls, subscripts — those are not stable bindings to track)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def terminal_name(node: ast.AST) -> Optional[str]:
    """Last component of a Name/Attribute chain ('c' for ``a.b.c``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def iter_functions(tree: ast.Module) -> Iterator[Tuple[str, ast.AST,
                                                       Optional[str]]]:
    """Yield ``(qualname, func_node, enclosing_class_name)`` for every
    def/async-def in the module, depth-first."""

    def walk(node, prefix: str, cls: Optional[str]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child, cls
                yield from walk(child, f"{q}.", cls)
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.", child.name)
            else:
                yield from walk(child, prefix, cls)

    yield from walk(tree, "", None)


def literal_int_or_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Resolve a ``donate_argnums`` / ``static_argnums`` literal: an int
    or a tuple/list of ints. None when it is computed (not analysable)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            got = literal_int_or_tuple(e)
            if got is None or len(got) != 1:
                return None
            out.append(got[0])
        return tuple(out)
    return None


def literal_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """A ``static_argnames`` literal: a string or tuple/list of strings."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return tuple(out)
    return None


def is_jax_jit(node: ast.AST) -> bool:
    """True for a reference to ``jax.jit`` (or bare ``jit`` imported
    from jax — fixtures use both spellings)."""
    return dotted(node) in ("jax.jit", "jit")


def jit_call_info(call: ast.Call):
    """If ``call`` constructs a jitted function, return
    ``(target_node, donate, static_nums, static_names)`` where target is
    the wrapped callable (Name/Lambda/def-ref) and the rest are resolved
    keyword literals (None when absent/computed). Handles both
    ``jax.jit(f, ...)`` and ``functools.partial(jax.jit, ...)`` (the
    decorator spelling — no target).
    """
    if not isinstance(call, ast.Call):
        return None
    target = None
    if is_jax_jit(call.func):
        target = call.args[0] if call.args else None
    elif dotted(call.func) in ("functools.partial", "partial") \
            and call.args and is_jax_jit(call.args[0]):
        target = call.args[1] if len(call.args) > 1 else None
    else:
        return None
    donate = static_nums = static_names = None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            donate = literal_int_or_tuple(kw.value)
        elif kw.arg == "static_argnums":
            static_nums = literal_int_or_tuple(kw.value)
        elif kw.arg == "static_argnames":
            static_names = literal_str_tuple(kw.value)
    return target, donate, static_nums, static_names


# ------------------------------------------------------------------ baseline
@dataclass(frozen=True)
class BaselineEntry:
    """One suppression: ``pass | path | scope-glob | detail-glob |
    justification``. Globs are fnmatch patterns against the finding's
    qualname / detail, so one justified entry can cover e.g. every
    lock-free read a documented method performs — without ever
    suppressing the same pattern in code it was not written for."""
    pass_id: str
    path: str
    scope: str
    detail: str
    justification: str
    lineno: int

    def matches(self, f: Finding) -> bool:
        return (self.pass_id == f.pass_id
                and fnmatch.fnmatchcase(f.path, self.path)
                and fnmatch.fnmatchcase(f.qualname, self.scope)
                and fnmatch.fnmatchcase(f.detail, self.detail))


@dataclass
class Baseline:
    """Parsed baseline file + bookkeeping of which entries fired.

    ``errors`` carries format problems (wrong field count, empty
    justification) — ``--strict`` fails on them, because an unjustified
    suppression is indistinguishable from a swept-under-the-rug bug.
    """
    entries: List[BaselineEntry] = field(default_factory=list)
    errors: List[str] = field(default_factory=list)
    _hits: Dict[BaselineEntry, int] = field(default_factory=dict)

    @classmethod
    def load(cls, path: Optional[Path]) -> "Baseline":
        bl = cls()
        if path is None or not Path(path).exists():
            return bl
        for i, raw in enumerate(Path(path).read_text().splitlines(), 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = [p.strip() for p in line.split("|", 4)]
            if len(parts) != 5:
                bl.errors.append(
                    f"{path}:{i}: expected 'pass | path | scope | detail "
                    f"| justification', got {len(parts)} field(s)")
                continue
            entry = BaselineEntry(*parts[:4], justification=parts[4],
                                  lineno=i)
            if not entry.justification:
                bl.errors.append(f"{path}:{i}: empty justification — every "
                                 f"suppression must say why it is safe")
            bl.entries.append(entry)
        return bl

    def filter(self, findings: Sequence[Finding]) -> List[Finding]:
        """Drop suppressed findings, recording which entries fired."""
        kept = []
        for f in findings:
            entry = next((e for e in self.entries if e.matches(f)), None)
            if entry is None:
                kept.append(f)
            else:
                self._hits[entry] = self._hits.get(entry, 0) + 1
        return kept

    def unused(self) -> List[BaselineEntry]:
        """Entries that suppressed nothing this run — stale once the
        underlying code is fixed; ``--strict`` requires their removal."""
        return [e for e in self.entries if e not in self._hits]
