"""Trace-safety pass (``trace``): host syncs inside jitted functions.

A jitted function body runs once at trace time with abstract tracers.
Anything that needs a *concrete* value — ``.item()``, ``float()/int()/
bool()`` on an array, ``np.asarray`` — either blocks on a device→host
transfer every call (killing the latency the paper measures) or raises
``TracerConversionError`` only on the first real trace. ``time.time``
inside a trace is worse: it runs once and bakes a constant timestamp
into the compiled program. Python ``if``/``while`` on a traced value is
the classic ``ConcretizationTypeError``.

Roots are functions the repo *directly* jits — ``@jax.jit`` /
``@functools.partial(jax.jit, ...)`` decorators, ``jax.jit(f, ...)``
references resolved to defs in the same module — no transitive
call-graph propagation (helpers that also run under trace are covered
where it matters: they are jitted themselves). Taint starts at the
traced parameters (all params minus ``static_argnums`` /
``static_argnames``) and flows through assignments. Reads that produce
static values stay clean: ``x.shape`` / ``.ndim`` / ``.dtype`` /
``.size``, ``len(x)``, and ``x is None`` comparisons (resolved at trace
time, no sync).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, Module, dotted, iter_functions,
                                 jit_call_info, register)

#: calls that are host-side no matter what they are applied to
_HOST_CALLS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "time.time", "time.perf_counter", "time.monotonic",
}

#: attribute reads yielding static (trace-time) values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def _param_names(fn) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _jit_roots(mod: Module) -> Dict[ast.AST, Set[str]]:
    """Map directly-jitted def nodes -> static parameter names."""
    by_name: Dict[str, ast.AST] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, node)

    roots: Dict[ast.AST, Set[str]] = {}

    def add(fn, static_nums, static_names):
        statics = roots.setdefault(fn, set())
        params = _param_names(fn)
        for i in static_nums or ():
            if 0 <= i < len(params):
                statics.add(params[i])
        statics.update(static_names or ())

    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if dotted(dec) in ("jax.jit", "jit"):
                    add(node, None, None)
                elif isinstance(dec, ast.Call):
                    info = jit_call_info(dec)
                    if info:
                        add(node, info[2], info[3])
        elif isinstance(node, ast.Call):
            info = jit_call_info(node)
            if info and isinstance(info[0], ast.Name) \
                    and info[0].id in by_name:
                add(by_name[info[0].id], info[2], info[3])
    return roots


class _Taint(ast.NodeVisitor):
    """Is any tainted name read by this expression, ignoring reads that
    produce static values?"""

    def __init__(self, taint: Set[str]):
        self.taint = taint
        self.hit: Optional[ast.Name] = None

    def visit_Name(self, node):
        if isinstance(node.ctx, ast.Load) and node.id in self.taint \
                and self.hit is None:
            self.hit = node

    def visit_Attribute(self, node):
        if node.attr in _STATIC_ATTRS:
            return                      # x.shape et al. are trace-static
        self.generic_visit(node)

    def visit_Call(self, node):
        if isinstance(node.func, ast.Name) and node.func.id == "len":
            return                      # len(x) is the static leading dim
        self.generic_visit(node)

    def visit_Compare(self, node):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return                      # `x is None` resolves at trace time
        self.generic_visit(node)

    def visit_Lambda(self, node):       # deferred; not this trace step
        pass

    visit_FunctionDef = visit_Lambda
    visit_AsyncFunctionDef = visit_Lambda


def _tainted(expr, taint: Set[str]) -> Optional[ast.Name]:
    v = _Taint(taint)
    v.visit(expr)
    return v.hit


@register
class TracePass:
    name = "trace"
    description = ("host syncs (.item(), float/int/bool on arrays, "
                   "np.asarray, time.time) and Python control flow on "
                   "traced values inside jitted functions")

    def run(self, modules: Sequence[Module]) -> List[Finding]:
        findings: List[Finding] = []
        for mod in modules:
            roots = _jit_roots(mod)
            if not roots:
                continue
            quals = {fn: q for q, fn, _c in iter_functions(mod.tree)}
            for fn, statics in roots.items():
                findings.extend(self._check_root(
                    mod, quals.get(fn, fn.name), fn, statics))
        return findings

    def _check_root(self, mod, qual, fn, statics: Set[str]):
        findings: List[Finding] = []
        seen: Set[Tuple[int, int, str]] = set()
        taint: Set[str] = {p for p in _param_names(fn)
                           if p not in statics and p not in ("self", "cls")}
        taint.update(p.arg for p in fn.args.kwonlyargs
                     if p.arg not in statics)

        def flag(node, detail, message, hint):
            key = (node.lineno, node.col_offset, detail)
            if key not in seen:
                seen.add(key)
                findings.append(Finding(
                    self.name, mod.rel, node.lineno, node.col_offset,
                    qual, detail, message, hint))

        def check_expr(expr):
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                path = dotted(node.func)
                if path in _HOST_CALLS:
                    flag(node, path,
                         f"`{path}` inside jitted `{fn.name}` runs on the "
                         f"host: a forced device sync (or, for time.*, a "
                         f"constant baked in at trace time)",
                         hint="move host-side work outside the jitted "
                              "function, or use jnp equivalents")
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    flag(node, ".item()",
                         f"`.item()` inside jitted `{fn.name}` forces a "
                         f"device→host sync on every call",
                         hint="keep the value as a traced array; convert "
                              "outside the jit boundary")
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in ("float", "int", "bool") \
                        and node.args:
                    hit = _tainted(node.args[0], taint)
                    if hit is not None:
                        flag(node, f"{node.func.id}({hit.id})",
                             f"`{node.func.id}()` on traced value "
                             f"`{hit.id}` inside jitted `{fn.name}` is a "
                             f"host sync (TracerConversionError on "
                             f"abstract tracers)",
                             hint="use jnp ops on the traced value, or "
                                  "mark the parameter static")

        def walk_block(body):
            for stmt in body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                    continue
                if isinstance(stmt, (ast.If, ast.While)):
                    check_expr(stmt.test)
                    hit = _tainted(stmt.test, taint)
                    if hit is not None:
                        kw = "while" if isinstance(stmt, ast.While) else "if"
                        flag(stmt, f"{kw} {hit.id}",
                             f"Python `{kw}` on traced value `{hit.id}` "
                             f"inside jitted `{fn.name}` raises "
                             f"ConcretizationTypeError at trace time",
                             hint="use jnp.where / lax.cond / lax."
                                  "while_loop, or mark the parameter "
                                  "static")
                    walk_block(stmt.body)
                    walk_block(getattr(stmt, "orelse", []))
                elif isinstance(stmt, ast.For):
                    check_expr(stmt.iter)
                    # the loop *target* is not treated as traced: repo
                    # loops iterate static ranges / layer lists
                    walk_block(stmt.body)
                    walk_block(stmt.body)
                    walk_block(stmt.orelse)
                elif isinstance(stmt, ast.With):
                    for item in stmt.items:
                        check_expr(item.context_expr)
                    walk_block(stmt.body)
                elif isinstance(stmt, ast.Try):
                    walk_block(stmt.body)
                    for h in stmt.handlers:
                        walk_block(h.body)
                    walk_block(stmt.orelse)
                    walk_block(stmt.finalbody)
                elif isinstance(stmt, (ast.Assign, ast.AnnAssign,
                                       ast.AugAssign)):
                    value = stmt.value
                    if value is not None:
                        check_expr(value)
                    targets = stmt.targets if isinstance(stmt, ast.Assign) \
                        else [stmt.target]
                    names = [n.id for t in targets
                             for n in ast.walk(t)
                             if isinstance(n, ast.Name)]
                    if value is not None and _tainted(value, taint):
                        taint.update(names)
                    elif isinstance(stmt, ast.Assign):
                        for n in names:   # overwritten with a static value
                            taint.discard(n)
                else:
                    check_expr(stmt)

        walk_block(fn.body)
        return findings
