from repro.parallel.sharding import (  # noqa: F401
    MeshRules,
    current_rules,
    param_partition_specs,
    set_rules,
    shard_activation,
    use_rules,
)
