"""Sharding rules: logical activation/parameter axes -> mesh axes.

The framework uses Megatron-style tensor parallelism on the ``model`` mesh axis
and batch (data) parallelism over ``data`` (and ``pod``, when multi-pod).

Rules are carried by a ``MeshRules`` context so model code can annotate
activations without knowing the mesh (or whether there is one: on a bare CPU
run the context is None and annotations are no-ops).
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)   # ('pod','data') when multi-pod
    model_axis: str = "model"
    # arch policy, derived from divisibility (see rules_for)
    shard_attn_heads: bool = True
    shard_kv_heads: bool = True
    expert_mode: str = "expert"               # 'expert' | 'tensor'
    # beyond-paper: ZeRO-1 — shard optimizer moments over the data axis
    zero1: bool = True

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]


_tls = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_tls, "rules", None)


def set_rules(rules: Optional[MeshRules]) -> None:
    _tls.rules = rules


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def shard_activation(x, *logical: Optional[str]):
    """Annotate an activation. ``logical`` entries: 'batch', 'model', 'seq',
    None. 'seq' maps to the data axis only for single-batch long-context
    (sequence sharding); otherwise None.

    Dims that don't divide their mesh axis are left unsharded — uneven
    GSPMD sharding triggers 'involuntary full rematerialization' copies
    (§Perf iteration A: stablelm kv=8 on a 16-way axis cost ~1.6 GB/layer
    of decode all-gathers before this guard)."""
    rules = current_rules()
    if rules is None:
        return x
    axes = []
    for l, dim in zip(logical, x.shape):
        if l == "batch" or l == "seq":
            ax = (rules.batch_axes if len(rules.batch_axes) > 1
                  else rules.batch_axes[0])
        elif l == "model":
            ax = rules.model_axis
        else:
            axes.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= rules.mesh.shape[a]
        axes.append(ax if dim % size == 0 and dim >= size else None)
    spec = P(*axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter partition specs, by path.
#
# Param pytrees are nested dicts; jax.tree_util paths like
# "blocks/blk0/attn/wq" are matched against the rules below. All block params
# are stacked over a leading period axis (never sharded), so specs get a
# leading None when `stacked` is set for that subtree.
# ---------------------------------------------------------------------------

def _spec_for(path: str, rules: MeshRules) -> P:
    m = rules.model_axis
    ah = m if rules.shard_attn_heads else None
    kh = m if (rules.shard_attn_heads and rules.shard_kv_heads) else None

    table = [
        # embeddings / lm head: vocab-parallel
        (r"embed/table$",            P(m, None)),
        (r"lm_head/w$",              P(None, m)),
        (r"pos_embed/table$",        P(None, None)),
        # attention. wq: (d, Hq, hd) ; wk/wv: (d, Hkv, hd) ; wo: (Hq, hd, d)
        # fused wqkv: (d, G, gq+2, hd) — kv-group column parallel
        (r"(attn|self_attn|cross_attn)/wqkv$", P(None, ah, None, None)),
        (r"(attn|self_attn|cross_attn)/bqkv$", P(ah, None, None)),
        (r"(attn|self_attn|cross_attn)/wq$", P(None, ah, None)),
        (r"(attn|self_attn|cross_attn)/w[kv]$", P(None, kh, None)),
        (r"(attn|self_attn|cross_attn)/wo$", P(ah, None, None)),
        (r"(attn|self_attn|cross_attn)/b[qkv]$", P(ah, None) if ah else P(None, None)),
        # dense mlp: column-parallel in (fused gate|up), row-parallel out
        (r"mlp/w_in$",               P(None, None, m)),
        (r"mlp/w_(gate|up)$",        P(None, m)),
        (r"mlp/w_down$",             P(m, None)),
        # MoE
        (r"moe/router$",             P(None, None)),
        (r"moe/experts/w_in$",
         P(m, None, None, None) if rules.expert_mode == "expert"
         else P(None, None, None, m)),
        (r"moe/experts/w_down$",
         P(m, None, None) if rules.expert_mode == "expert" else P(None, m, None)),
        (r"moe/shared/w_(gate|up)$", P(None, m)),
        (r"moe/shared/w_down$",      P(m, None)),
        # xLSTM mLSTM: qkv shard the head_dim (head counts are small),
        # in/out projections column/row parallel
        (r"mlstm/w_in$",             P(None, m)),
        (r"mlstm/w_out$",            P(m, None)),
        (r"mlstm/w[qkv]$",           P(None, None, m)),
        (r"mlstm/(w_ogate|skip)$",   P(None, m)),
        (r"mlstm/(b_igate|b_fgate|w_igate|w_fgate)$", P(None)),
        # sLSTM: recurrent dense kernels — head-sharded
        (r"slstm/w_[izfo]$",         P(None, m, None)),
        (r"slstm/r_[izfo]$",         P(m, None, None)),
        (r"slstm/b_[izfo]$",         P(m, None)),
        (r"slstm/ffn/w_(gate|up)$",  P(None, m)),
        (r"slstm/ffn/w_down$",       P(m, None)),
        (r"slstm/(w_in|w_out)$",     P(None, None)),
        # RG-LRU block
        (r"rglru/w_(x|gate)$",       P(None, m)),
        (r"rglru/w_out$",            P(m, None)),
        (r"rglru/(a_param|conv_w|conv_b|gate_a/.*|gate_x/.*)$", P(None)),
        # norms, scalars
        (r"(norm|ln)[^/]*/(scale|bias)$", P(None)),
        (r".*", P()),
    ]
    for pat, spec in table:
        if re.search(pat, path):
            return spec
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_partition_specs(params, rules: MeshRules, stacked_prefixes=("blocks", "enc_blocks")):
    """PartitionSpec pytree matching ``params``. Subtrees under a stacked
    prefix get a leading None axis (the scan/period axis)."""
    def one(path, leaf):
        ps = _path_str(path)
        spec = _spec_for(ps, rules)
        top = ps.split("/", 1)[0]
        if top in stacked_prefixes:
            spec = P(None, *spec)
        # norm scale inside stacked blocks ends up P(None, None) etc - fine.
        if leaf.ndim < len(spec):
            # scalars / fewer dims than spec: trim trailing Nones
            spec = P(*tuple(spec)[: leaf.ndim])
        elif leaf.ndim > len(spec):
            spec = P(*(tuple(spec) + (None,) * (leaf.ndim - len(spec))))
        # divisibility guard: demote any axis the tensor can't honour
        # (e.g. 4 mLSTM heads or 20 whisper heads on a 16-way model axis)
        entries = []
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                entries.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= rules.mesh.shape[a]
            entries.append(ax if dim % size == 0 and dim >= size else None)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, params)


def named_shardings(params, rules: MeshRules):
    specs = param_partition_specs(params, rules)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def rules_for(cfg, mesh, multi_pod: bool = False) -> MeshRules:
    """Derive the arch sharding policy from divisibility against the mesh."""
    msize = mesh.shape["model"]
    shard_attn = cfg.n_heads % msize == 0
    shard_kv = shard_attn and cfg.n_kv_heads % msize == 0
    mode = "expert"
    if cfg.moe is not None:
        if cfg.moe.sharding != "auto":
            mode = cfg.moe.sharding
        elif cfg.moe.num_experts % msize != 0:
            mode = "tensor"
    return MeshRules(
        mesh=mesh,
        batch_axes=("pod", "data") if multi_pod else ("data",),
        shard_attn_heads=shard_attn,
        shard_kv_heads=shard_kv,
        expert_mode=mode,
    )
