"""Typed request lifecycle for the serving engine (API v2).

The paper's core finding is that *per-request* latency under concurrency
load decides whether a low-cost deployment is viable — so the engine's
public surface is request-centric, not token-array-centric:

    ``GenerationRequest`` (tokens + per-request ``SamplingParams``)
        -> ``engine.generate(...)`` -> ``RequestHandle``
        -> ``handle.result()`` -> ``GenerationResult``

``RequestHandle`` is future-compatible (``result``/``done``/``cancel``) and
additionally a thread-safe streaming iterator: ``for tok in handle`` yields
generated token ids as decode segments complete, long before the request
finishes. ``GenerationResult`` carries the finish reason and the per-phase
timing breakdown (queue wait / prefill / decode) that the paper's
wall-clock-only tables (Fig. 7, Tables 2-4) cannot see.
"""
from __future__ import annotations

import dataclasses
import queue
from concurrent.futures import CancelledError, Future
from threading import Event
from typing import Iterator, List, Optional, Protocol

import numpy as np

FINISH_LENGTH = "length"
FINISH_EOS = "eos"
FINISH_CANCELLED = "cancelled"


class HeadFn(Protocol):
    """Contract for ``ServingEngine``'s optional output head.

    Called inside the jitted encoder function as ``head_fn(params, hidden,
    mask)`` with the *full* parameter tree (not just the encoder's), the
    final hidden states ``(B, S, d_model)`` and the validity mask ``(B, S)``
    (True on real, non-padding tokens); returns the per-request payload
    (any pytree with a leading batch axis).
    """

    def __call__(self, params, hidden, mask): ...


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs.

    max_new_tokens: emission budget; None = the engine's default; must not
        exceed the engine's ``max_new_tokens`` (KV slots are sized for it).
    eos_id: stop token — the row retires as soon as it *emits* this id
        (the eos token is included in the output); None disables.
    temperature: 0.0 = greedy argmax; > 0 samples softmax(logits / T).
    top_k: restrict sampling to the k highest logits; None/0 disables.
    seed: PRNG seed for sampling. Tokens are drawn with a counter-based
        key (seed, absolute position), so a given (prompt, seed) is
        reproducible regardless of batching or segment boundaries.
    """
    max_new_tokens: Optional[int] = None
    eos_id: Optional[int] = None
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: int = 0

    def validate(self, engine_max_new_tokens: int) -> int:
        """Return the effective token budget, raising on bad params."""
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k is not None and self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 or None, got {self.top_k}")
        n = (engine_max_new_tokens if self.max_new_tokens is None
             else self.max_new_tokens)
        if n < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {n}")
        if n > engine_max_new_tokens:
            raise ValueError(
                f"max_new_tokens={n} exceeds the engine's limit "
                f"({engine_max_new_tokens}); KV slots are sized for it — "
                f"raise EngineConfig.max_new_tokens")
        return n


@dataclasses.dataclass(frozen=True)
class GenerationRequest:
    """A typed generation request: prompt tokens + how to decode them.

    tokens: 1-D int32 prompt ids. Must be non-empty and fit the engine's
        largest pad bucket (otherwise the handle resolves with
        ``ValueError`` / ``RequestTooLong`` — ``generate()`` itself never
        raises mid-burst).
    sampling: per-request ``SamplingParams`` (budget, stop token,
        temperature/top-k/seed); the default decodes greedily to the
        engine's ``max_new_tokens``.
    priority: admission order — higher-priority requests are admitted
        (and un-parked from the admission overflow queue) first; FIFO
        within a level. Does not preempt requests already decoding.
    request_id: optional caller tag, echoed on ``GenerationResult`` —
        the engine never interprets it.
    """
    tokens: np.ndarray
    sampling: SamplingParams = SamplingParams()
    priority: int = 0
    request_id: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class RequestTiming:
    """Per-phase latency breakdown (seconds) — the decomposition the
    paper's end-to-end ladder cannot observe. In batch-at-a-time mode
    prefill and decode are one fused dispatch, so ``prefill_s`` is 0 and
    ``decode_s`` carries the whole serve time."""
    queue_s: float
    prefill_s: float
    decode_s: float

    @property
    def total_s(self) -> float:
        return self.queue_s + self.prefill_s + self.decode_s


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """What a finished request resolves to (``handle.result()``).

    tokens: generated ids, prompt excluded (the eos token is included
        when ``finish_reason == 'eos'``; a cancelled request keeps the
        tokens it produced before the cancel took effect).
    finish_reason: ``'length'`` (budget exhausted) | ``'eos'`` |
        ``'cancelled'``.
    timing: the per-phase ``RequestTiming`` breakdown.
    request_id: echoed from the ``GenerationRequest``.
    """
    tokens: np.ndarray
    finish_reason: str
    timing: RequestTiming
    request_id: Optional[str] = None


_STREAM_END = object()


class RequestHandle:
    """Client-side view of one in-flight generation request.

    Future-compatible — ``result(timeout)`` blocks for the
    ``GenerationResult`` (raising the request's exception, e.g.
    ``RequestTooLong``), ``done()``/``cancelled()``/``add_done_callback``
    delegate to the underlying future — plus a thread-safe streaming
    iterator: ``for tok in handle`` yields token ids as the engine
    completes decode segments (single consumer; iterating from several
    threads splits the stream between them). The iterator ends when the
    request finishes or is cancelled, and re-raises the request's
    exception if it failed.
    """

    def __init__(self, request: GenerationRequest, future: Future):
        self.request = request            # guarded-by: init
        self.future = future              # guarded-by: threadsafe
        self._stream: "queue.Queue" = queue.Queue()  # guarded-by: threadsafe
        self._cancel = Event()            # guarded-by: threadsafe
        future.add_done_callback(lambda _f: self._stream.put(_STREAM_END))

    # ---------------------------------------------------- future protocol
    def result(self, timeout: Optional[float] = None) -> GenerationResult:
        return self.future.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self.future.exception(timeout)

    def done(self) -> bool:
        return self.future.done()

    def cancelled(self) -> bool:
        return self.future.cancelled() or (
            self.future.done() and not self.future.exception()
            and self.future.result().finish_reason == FINISH_CANCELLED)

    def add_done_callback(self, fn) -> None:
        self.future.add_done_callback(fn)

    def cancel(self) -> bool:
        """Cancel the request. Before it starts running this resolves the
        future as cancelled; mid-decode it flags the row, which the
        scheduler retires at the next segment boundary with
        ``finish_reason='cancelled'`` (partial tokens preserved; for the
        batch-at-a-time worker the whole serve is one segment, so the
        result carries its full output under that reason). Returns True
        unless the request already finished."""
        self._cancel.set()
        if self.future.cancel():
            return True
        return not self.future.done()

    @property
    def cancel_requested(self) -> bool:
        return self._cancel.is_set()

    # ---------------------------------------------------------- streaming
    def _push(self, tokens) -> None:
        """Engine-side: publish a completed segment's tokens."""
        for t in tokens:
            self._stream.put(int(t))

    def __iter__(self) -> Iterator[int]:
        while True:
            item = self._stream.get()
            if item is _STREAM_END:
                # re-arm the sentinel: later (or concurrent) iterations
                # must also terminate instead of blocking forever
                self._stream.put(_STREAM_END)
                break
            yield item
        if self.future.done() and not self.future.cancelled():
            exc = self.future.exception()
            if exc is not None:
                raise exc

    def stream(self) -> Iterator[int]:
        """Alias for ``iter(handle)``."""
        return iter(self)


def collect(handles: List[RequestHandle], timeout: Optional[float] = None
            ) -> List[GenerationResult]:
    """Gather results for a list of handles (CancelledError -> None)."""
    out = []
    for h in handles:
        try:
            out.append(h.result(timeout))
        except CancelledError:
            out.append(None)
    return out
