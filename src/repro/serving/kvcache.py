"""KV-cache pool with request-slot management for continuous batching.

The cache pytree itself is built by ``models.make_caches`` (per-pattern
stacked ring buffers / recurrent states); this module adds the pool view the
engine uses: a fixed batch of slots, per-slot request ids and lengths, and
reset-on-assign semantics so a finished request's slot is immediately
reusable without reallocating device buffers. ``assign_many`` resets a whole
batch of slots in one fused device call (vs one ``make_caches`` allocation
sweep per batch — the per-batch tax the engine used to pay), and
``batch_view``/``write_back`` give the engine a contiguous batch-sized view
of the assigned slots, and ``compact_view``/``scatter_back`` the
tier-width view the occupancy-adaptive decode segment runs on: gather the
live slots (padded to the tier with inert duplicates), decode at that
width, scatter only the live prefix back — slots outside the compact set
are never written.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import make_caches


def _scatter_template(caches, template, idx):
    """Scatter the single-slot template into slots ``idx`` (int32 (n,)) of
    every leaf — the one definition of what 'reset' means."""
    n = idx.shape[0]
    return jax.tree.map(
        lambda x, t: x.at[:, idx].set(
            jnp.broadcast_to(t[:, :1], (t.shape[0], n) + t.shape[2:])),
        caches, template)


@functools.partial(jax.jit, donate_argnums=0)
def _reset_slots(caches, template, idx):
    """Reset slots in one fused scatter per leaf; the pool is donated so
    the scatter updates in place instead of copying all n_slots."""
    return _scatter_template(caches, template, idx)


@functools.partial(jax.jit, donate_argnums=0)
def _reset_and_view(caches, template, idx):
    """Fused reset-on-assign + batch view (gather): one device dispatch per
    acquire (vs an eager per-leaf allocation sweep in make_caches)."""
    caches = _scatter_template(caches, template, idx)
    view = jax.tree.map(lambda x: jnp.take(x, idx, axis=1), caches)
    return caches, view


@functools.partial(jax.jit, donate_argnums=0,
                   static_argnames=("lo", "n"))
def _reset_and_view_run(caches, template, *, lo, n):
    """Contiguous-slot fast path: reset via one dynamic_update_slice region
    and view via a static slice (no gather)."""
    caches = jax.tree.map(
        lambda x, t: jax.lax.dynamic_update_slice_in_dim(
            x, jnp.broadcast_to(t[:, :1], (t.shape[0], n) + t.shape[2:]),
            lo, axis=1),
        caches, template)
    view = jax.tree.map(
        lambda x: jax.lax.slice_in_dim(x, lo, lo + n, axis=1), caches)
    return caches, view


@functools.partial(jax.jit, donate_argnums=0)
def _write_slots(caches, batch, idx):
    return jax.tree.map(lambda x, b: x.at[:, idx].set(b), caches, batch)


@jax.jit
def _take_slots(caches, idx):
    """Fused batch-view gather: one device dispatch per view (vs an eager
    per-leaf ``jnp.take`` sweep), specializing on the slot *count* only —
    chunked prefill gathers its fill batch's staged slots every chunk, at
    arbitrary (fragmenting) offsets."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=1), caches)


@functools.partial(jax.jit, donate_argnums=0)
def _scatter_prefix(caches, batch, idx):
    """Scatter the first ``len(idx)`` rows of a (possibly wider) batch view
    back into pool slots ``idx`` — the compacted decode segment's
    write-back. The view may carry padding rows beyond the prefix (the
    occupancy-to-tier round-up); they are never written, so pool slots
    outside ``idx`` stay bitwise untouched. Specializes per
    (view width, slot count); the pool is donated so the scatter updates
    in place."""
    n = idx.shape[0]
    return jax.tree.map(
        lambda x, b: x.at[:, idx].set(jax.lax.slice_in_dim(b, 0, n, axis=1)),
        caches, batch)


class CachePool:
    def __init__(self, cfg, n_slots: int, max_len: int, *, long_ctx=False,
                 dtype=jnp.bfloat16, kv_quant=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.kv_quant = kv_quant
        # kv_quant="int8": slots hold int8 K/V plus per-(position, head)
        # scale planes — extra leaves that every pool helper (reset/take/
        # gather/scatter, tier views, prefix load/store) already carries,
        # being leaf-generic tree maps over the cache dict.
        self.caches = make_caches(cfg, n_slots, max_len, long_ctx=long_ctx,
                                  dtype=dtype, kv_quant=kv_quant)
        # single-slot template preserving per-leaf "empty" values (e.g. the
        # attention cache's pos = -1 sentinel)
        self._template = make_caches(cfg, 1, max_len, long_ctx=long_ctx,
                                     dtype=dtype, kv_quant=kv_quant)
        self.request_of = [None] * n_slots       # slot -> request id
        self.lengths = [0] * n_slots

    # ------------------------------------------------------- single slot
    def assign(self, request_id) -> int:
        return self.assign_many([request_id])[0]

    def release(self, slot: int) -> None:
        self.request_of[slot] = None
        self.lengths[slot] = 0

    # -------------------------------------------------------- batch slots
    def _claim(self, request_ids: Sequence) -> List[int]:
        """Book-keep one free slot per request; prefers a contiguous run so
        views can slice instead of gather."""
        ids = list(request_ids)
        free = [i for i, r in enumerate(self.request_of) if r is None]
        if len(ids) > len(free):
            raise RuntimeError(
                f"CachePool exhausted: {len(ids)} requested, "
                f"{len(free)} of {self.n_slots} slots free")
        slots = self._contiguous_run(free, len(ids)) or free[:len(ids)]
        for rid, s in zip(ids, slots):
            self.request_of[s] = rid
            self.lengths[s] = 0
        return slots

    def assign_many(self, request_ids: Sequence) -> List[int]:
        """Claim one slot per request and reset them all in a single fused
        device op (reset-on-assign)."""
        slots = self._claim(request_ids)
        self.caches = _reset_slots(self.caches, self._template,
                                   jnp.asarray(slots, jnp.int32))
        return slots

    @staticmethod
    def _contiguous_run(free: List[int], n: int) -> Optional[List[int]]:
        run: List[int] = []
        for s in free:
            if run and s == run[-1] + 1:
                run.append(s)
            else:
                run = [s]
            if len(run) == n:
                return run
        return None

    def acquire(self, request_ids: Sequence, *, gather: bool = False):
        """assign_many + batch_view in one fused device call — the engine's
        per-batch fast path. Returns (slots, batch_caches). Contiguous slot
        runs (the common case: whole batches release together) take the
        slice path; fragmented pools fall back to a gather. ``gather=True``
        forces the gather variant: its jit specializes only on the slot
        *count*, not the (lo, n) run position, so callers that acquire at
        arbitrary offsets mid-serve (the continuous scheduler's
        prefill-into-slot) compile one variant per batch size instead of
        one per run position."""
        slots = self._claim(request_ids)
        lo, n = slots[0], len(slots)
        if not gather and slots == list(range(lo, lo + n)):
            self.caches, view = _reset_and_view_run(
                self.caches, self._template, lo=lo, n=n)
        else:
            self.caches, view = _reset_and_view(
                self.caches, self._template, jnp.asarray(slots, jnp.int32))
        return slots, view

    def release_many(self, slots: Sequence[int]) -> None:
        for s in slots:
            self.release(s)

    def batch_view(self, slots: Sequence[int], *, gather: bool = False):
        """Batch-sized cache pytree for the given slots (slot k of the view
        is pool slot slots[k]). Contiguous slots -> cheap slice; otherwise
        one fused jitted gather (compiled per slot count, not offsets).
        ``gather=True`` forces the gather: the eager slice compiles one
        tiny process-wide program per (offset, width, leaf shape) — fine
        for one-off views, but on a serving hot path every new slot
        arrangement pays that compile mid-request (the first-traffic
        warm-in), while the gather is jit-cached per slot *count* and
        primed by ``engine.warmup()``."""
        slots = list(slots)
        lo, n = slots[0], len(slots)
        if not gather and slots == list(range(lo, lo + n)):
            return jax.tree.map(
                lambda x: jax.lax.slice_in_dim(x, lo, lo + n, axis=1),
                self.caches)
        return _take_slots(self.caches, jnp.asarray(slots, jnp.int32))

    # ------------------------------------------- compacted decode segments
    def compact_view(self, slots: Sequence[int], width: int):
        """Tier-width cache view for a compacted decode segment: rows
        0..len(slots)-1 are the given slots (the live rows, in order); rows
        beyond are padding — duplicates of ``slots[0]`` that ride along
        inactive and are dropped by ``scatter_back``. Returns
        ``(idx, view)``: ``idx`` is the width-length gather order the view
        was built with, and callers must gather their per-row state by the
        same order — taking it from here (instead of re-deriving the
        padding convention) keeps cache rows and state rows structurally
        aligned. Always the fused ``_take_slots`` gather (never the
        contiguous-slice fast path), so jit specializes on ``width``
        alone: one compiled variant per tier, not per slot arrangement."""
        slots = list(slots)
        if not 0 < len(slots) <= width:
            raise ValueError(f"{len(slots)} slots do not fit width {width}")
        idx = slots + [slots[0]] * (width - len(slots))
        return idx, _take_slots(self.caches, jnp.asarray(idx, jnp.int32))

    def scatter_back(self, slots: Sequence[int], batch_caches,
                     lengths: Optional[Sequence[int]] = None) -> None:
        """Write a compacted segment's result back to the home slots: only
        the first ``len(slots)`` view rows land (padding rows are sliced
        away in-graph), so every slot outside ``slots`` — free, prefilling,
        or retired — keeps its KV bitwise. The counterpart of
        ``compact_view``; ``write_back`` stays the whole-view path."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.caches = _scatter_prefix(self.caches, batch_caches, idx)
        if lengths is not None:
            for s, n in zip(slots, lengths):
                self.lengths[s] = int(n)

    def scatter_rollback(self, slots: Sequence[int], batch_caches,
                         boundaries: Sequence[int],
                         lengths: Optional[Sequence[int]] = None) -> None:
        """``scatter_back`` with a per-row KV truncate: row k of the view
        lands in slot ``slots[k]`` with every cached position >=
        ``boundaries[k]`` reset to the empty sentinel (and ``len``
        clamped). Speculative decoding's per-row accept/rollback — one
        fused op replaces write-back-then-truncate — and keeps the same
        untouched-slots-stay-bitwise contract as ``scatter_back`` (padding
        view rows beyond ``len(slots)`` are sliced away in-graph)."""
        self.caches = _scatter_rollback(
            self.caches, batch_caches, jnp.asarray(list(slots), jnp.int32),
            jnp.asarray(list(boundaries), jnp.int32))
        if lengths is not None:
            for s, n in zip(slots, lengths):
                self.lengths[s] = int(n)

    def write_back(self, slots: Sequence[int], batch_caches,
                   lengths: Optional[Sequence[int]] = None) -> None:
        """Store a batch view's (updated) caches back into the pool slots —
        the persistence hook for step-granularity continuous batching.
        Chunk-granular by design: chunked prefill calls this once per
        prompt chunk with the fill's staged caches and its partial
        ``lengths`` (tokens staged so far), so pool bookkeeping tracks
        prefill progress, not just completed prompts."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.caches = _write_slots(self.caches, batch_caches, idx)
        if lengths is not None:
            for s, n in zip(slots, lengths):
                self.lengths[s] = int(n)

    def claim(self, request_ids: Sequence) -> List[int]:
        """Book slots WITHOUT the device-side reset — for callers that will
        immediately overwrite the whole slot (the prefix store's
        copy-on-reference load). Saves the reset scatter that
        ``assign_many`` pays."""
        return self._claim(request_ids)

    @property
    def free_slots(self) -> int:
        return self.request_of.count(None)


# ---------------------------------------------------------- prefix store
#
# Shared-prompt KV reuse: prompts are hashed at ``prefill_chunk``-token
# granularity into a radix trie; a joining request that shares a cached
# prefix copies the stored KV into its lane slot in one fused
# gather/scatter (the ``compact_view``/``scatter_back`` idiom) and
# prefills only the unseen suffix. Entries are refcounted while a load is
# in flight and evicted LRU-by-bytes against a capacity budget.

@functools.partial(jax.jit, donate_argnums=0)
def _load_slots(dst, src, dst_idx, src_idx):
    """Copy slots ``src_idx`` of pool ``src`` into slots ``dst_idx`` of
    pool ``dst`` — one fused gather+scatter per leaf. The destination is
    donated so the scatter updates in place; specializes on the slot
    *count* only (both index vectors are traced)."""
    return jax.tree.map(
        lambda d, s: d.at[:, dst_idx].set(jnp.take(s, src_idx, axis=1)),
        dst, src)


@functools.partial(jax.jit, donate_argnums=0)
def _store_prefix(dst, src, dst_idx, src_idx, n_tokens):
    """Copy slots ``src_idx`` of ``src`` into ``dst_idx`` of ``dst``,
    truncating the attention caches to the first ``n_tokens`` positions:
    ``pos`` entries >= n_tokens become the -1 empty sentinel and ``len``
    is clamped, so a stored prefix never exposes KV the donor wrote
    beyond the prefix boundary (whole-prompt ``attn_apply`` stamps valid
    ``pos`` values on every padded bucket position — harmless in a live
    slot, where decode overwrites position ``len`` before attending, but
    garbage if replayed as a prefix). Only sound for pure global-attention
    cache pytrees ({k, v, pos, len} per block); the engine gates the
    prefix cache to those configs."""
    def copy(d, s):
        out = {}
        for key in d:
            taken = jnp.take(s[key], src_idx, axis=1)
            if key == "pos":
                taken = jnp.where(taken < n_tokens, taken, -1)
            elif key == "len":
                taken = jnp.minimum(taken, n_tokens)
            out[key] = d[key].at[:, dst_idx].set(taken)
        return out
    return {blk: copy(d, src[blk]) for blk, d in dst.items()}


@functools.partial(jax.jit, donate_argnums=0)
def _scatter_rollback(dst, src, idx, boundary):
    """Scatter the first ``len(idx)`` rows of a (possibly wider) batch view
    into pool slots ``idx``, truncating each row's attention cache to its
    own ``boundary`` (int32 (n,)) position count: ``pos`` entries >= the
    row's boundary become the -1 empty sentinel and ``len`` is clamped —
    the per-row generalization of ``_store_prefix``'s scalar truncation,
    fused with ``_scatter_prefix``'s padding-dropping write-back. This is
    speculative decoding's accept/rollback: a verify chunk writes KV for
    every proposed position, then each row keeps only its committed
    prefix, and the rollback re-establishes the invariant that positions
    at or past a row's frontier hold the empty sentinel (which the
    write-first verify chunk relies on). Slots outside ``idx`` stay
    bitwise untouched. Only sound for pure global-attention cache pytrees
    ({k, v[, scales], pos, len} per block); the engine gates spec-decode
    to those configs."""
    n = idx.shape[0]

    def copy(d, s):
        out = {}
        for key in d:
            taken = jax.lax.slice_in_dim(s[key], 0, n, axis=1)
            if key == "pos":                      # (n_periods, n, L)
                taken = jnp.where(taken < boundary[None, :, None], taken, -1)
            elif key == "len":                    # (n_periods, n)
                taken = jnp.minimum(taken, boundary[None, :])
            out[key] = d[key].at[:, idx].set(taken)
        return out
    return {blk: copy(d, src[blk]) for blk, d in dst.items()}


class PrefixEntry:
    """One stored prefix: ``n_tokens`` of KV in slot ``slot`` of the
    store's pool. ``refs`` guards in-flight loads against eviction;
    ``tick`` is the LRU stamp."""
    __slots__ = ("slot", "n_tokens", "nbytes", "refs", "tick", "node")

    def __init__(self, slot, n_tokens, nbytes, node):
        self.slot = slot
        self.n_tokens = n_tokens
        self.nbytes = nbytes
        self.refs = 0
        self.tick = 0
        self.node = node


class _TrieNode:
    __slots__ = ("key", "parent", "children", "entry")

    def __init__(self, key=None, parent=None):
        self.key = key          # chunk-token bytes (edge label from parent)
        self.parent = parent
        self.children = {}      # chunk bytes -> _TrieNode
        self.entry = None       # PrefixEntry stored at this depth, if any


class PrefixTrie:
    """Host-side bookkeeping for stored prefixes: a radix trie over
    ``chunk``-token chunks (node depth d = prompt prefix of d*chunk
    tokens). Pure bookkeeping — device slots live in ``PrefixStore``.
    Owned by the scheduler worker thread; not thread-safe."""

    def __init__(self, chunk: int, capacity_bytes: int):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        if capacity_bytes < 0:
            raise ValueError(f"capacity_bytes must be >= 0")
        self.chunk = chunk
        self.capacity = capacity_bytes
        self.root = _TrieNode()
        self.entries: List[PrefixEntry] = []
        self.bytes = 0
        self._tick = 0

    def _keys(self, tokens, n_chunks: int):
        toks = np.asarray(tokens, np.int32)
        C = self.chunk
        for i in range(n_chunks):
            yield toks[i * C:(i + 1) * C].tobytes()

    # ------------------------------------------------------------ lookup
    def lookup(self, tokens) -> Optional[PrefixEntry]:
        """Deepest stored entry strictly shorter than the prompt (a full
        match would leave no suffix token to produce the first logits
        from). Acquires a reference — the caller must ``release`` it once
        the KV copy has landed."""
        cap = max(0, (len(tokens) - 1) // self.chunk)
        node, best = self.root, None
        for key in self._keys(tokens, cap):
            node = node.children.get(key)
            if node is None:
                break
            if node.entry is not None:
                best = node.entry
        if best is not None:
            best.refs += 1
            self._tick += 1
            best.tick = self._tick
        return best

    def release(self, entry: PrefixEntry) -> None:
        if entry.refs <= 0:
            raise RuntimeError("release() without a matching lookup ref")
        entry.refs -= 1

    # ------------------------------------------------------------ insert
    def divergence_chunks(self, tokens) -> int:
        """Depth (in chunks) of the deepest existing trie node along the
        prompt's chunk path — where this prompt diverges from everything
        already stored. An entry inserted here is the longest prefix this
        prompt shares with any prior one."""
        n = len(tokens) // self.chunk
        depth, node = 0, self.root
        for i, key in enumerate(self._keys(tokens, n)):
            node = node.children.get(key)
            if node is None:
                break
            depth = i + 1
        return depth

    def has_entry(self, tokens, n_chunks: int) -> bool:
        node = self.root
        for key in self._keys(tokens, n_chunks):
            node = node.children.get(key)
            if node is None:
                return False
        return node.entry is not None

    def make_room(self, nbytes: int, min_evict: int = 0):
        """Evict LRU unreferenced entries until ``nbytes`` more fits the
        budget AND at least ``min_evict`` entries are freed (the store
        passes 1 when its slot pool is full). Returns the evicted entries
        (caller releases their device slots), or None — trie unchanged —
        when the demand cannot be met (all candidates referenced, or
        nbytes alone exceeds capacity)."""
        if nbytes > self.capacity:
            return None
        victims, freed = [], 0
        cands = sorted((e for e in self.entries if e.refs == 0),
                       key=lambda e: e.tick)
        i = 0
        while (self.bytes - freed + nbytes > self.capacity
               or len(victims) < min_evict):
            if i >= len(cands):
                return None
            victims.append(cands[i])
            freed += cands[i].nbytes
            i += 1
        for e in victims:
            self._remove(e)
        return victims

    def attach(self, tokens, n_chunks: int, nbytes: int,
               slot: int) -> PrefixEntry:
        """Create the entry for the first ``n_chunks`` chunks of
        ``tokens`` (path nodes are created as needed). The caller has
        already made room and copied the KV into ``slot``."""
        node = self.root
        for key in self._keys(tokens, n_chunks):
            child = node.children.get(key)
            if child is None:
                child = node.children[key] = _TrieNode(key, node)
            node = child
        if node.entry is not None:
            raise RuntimeError(f"entry already stored at depth {n_chunks}")
        entry = PrefixEntry(slot, n_chunks * self.chunk, nbytes, node)
        node.entry = entry
        self.entries.append(entry)
        self.bytes += nbytes
        self._tick += 1
        entry.tick = self._tick
        return entry

    def _remove(self, entry: PrefixEntry) -> None:
        node = entry.node
        node.entry = None
        self.entries.remove(entry)
        self.bytes -= entry.nbytes
        # prune now-empty path nodes so stale chunks don't count as
        # divergence points for future inserts
        while (node is not self.root and node.entry is None
               and not node.children):
            parent = node.parent
            del parent.children[node.key]
            node = parent


class PrefixStore:
    """Device half of the prefix cache for one pad bucket: a ``CachePool``
    holding stored prefixes plus the trie that indexes them. Slots have
    the same ``max_len`` as the lane pool so loads are shape-identical
    full-slot copies. Owned by the scheduler worker thread."""

    def __init__(self, cfg, n_slots: int, max_len: int, chunk: int, *,
                 capacity_bytes: Optional[int] = None, dtype=jnp.bfloat16,
                 kv_quant=None):
        self.pool = CachePool(cfg, n_slots, max_len, dtype=dtype,
                              kv_quant=kv_quant)
        self.entry_bytes = int(sum(x.nbytes
                                   for x in jax.tree.leaves(self.pool._template)))
        if capacity_bytes is None:
            capacity_bytes = n_slots * self.entry_bytes
        self.trie = PrefixTrie(chunk, capacity_bytes)
        self.chunk = chunk

    @property
    def bytes_used(self) -> int:
        return self.trie.bytes

    def lookup(self, tokens) -> Optional[PrefixEntry]:
        return self.trie.lookup(tokens)

    def release(self, entry: PrefixEntry) -> None:
        self.trie.release(entry)

    def load_many(self, entries: Sequence[PrefixEntry], dst_pool: CachePool,
                  dst_slots: Sequence[int]) -> None:
        """Copy-on-reference: one fused gather/scatter moving every
        entry's stored KV into its destination slot. Destination slots
        must be claimed but need no reset — the copy overwrites them
        fully (store slots carry the truncated-pos template semantics
        already)."""
        dst_pool.caches = _load_slots(
            dst_pool.caches, self.pool.caches,
            jnp.asarray(list(dst_slots), jnp.int32),
            jnp.asarray([e.slot for e in entries], jnp.int32))

    def insert(self, tokens, matched_tokens: int, src_pool: CachePool,
               src_slot: int):
        """Insert-on-complete. Two candidate depths per finished prompt:
        the divergence depth (the longest prefix shared with anything
        already in the trie — what the NEXT similar prompt will actually
        hit) and the full depth ``len(tokens)//chunk``. Each is stored
        only if strictly deeper than ``matched_tokens`` (what this
        request itself reused — re-storing that would duplicate an
        existing entry) and not already present. Returns
        (inserted, evicted) counts."""
        inserted = evicted = 0
        full = len(tokens) // self.chunk
        div = min(self.trie.divergence_chunks(tokens), full)
        depths = []
        for d in (div, full):
            if (d * self.chunk > matched_tokens and d not in depths
                    and not self.trie.has_entry(tokens, d)):
                depths.append(d)
        for d in depths:
            victims = self.trie.make_room(
                self.entry_bytes,
                min_evict=0 if self.pool.free_slots else 1)
            if victims is None:
                break                      # budget full of referenced entries
            for e in victims:
                self.pool.release(e.slot)
            evicted += len(victims)
            slot = self.pool.claim([("prefix", d)])[0]
            self.pool.caches = _store_prefix(
                self.pool.caches, src_pool.caches,
                jnp.asarray([slot], jnp.int32),
                jnp.asarray([src_slot], jnp.int32),
                jnp.asarray(d * self.chunk, jnp.int32))
            self.pool.lengths[slot] = d * self.chunk
            self.trie.attach(tokens, d, self.entry_bytes, slot)
            inserted += 1
        return inserted, evicted
