"""KV-cache pool with request-slot management for continuous batching.

The cache pytree itself is built by ``models.make_caches`` (per-pattern
stacked ring buffers / recurrent states); this module adds the pool view the
engine uses: a fixed batch of slots, per-slot request ids and lengths, and
reset-on-assign semantics so a finished request's slot is immediately
reusable without reallocating device buffers. ``assign_many`` resets a whole
batch of slots in one fused device call (vs one ``make_caches`` allocation
sweep per batch — the per-batch tax the engine used to pay), and
``batch_view``/``write_back`` give the engine a contiguous batch-sized view
of the assigned slots, and ``compact_view``/``scatter_back`` the
tier-width view the occupancy-adaptive decode segment runs on: gather the
live slots (padded to the tier with inert duplicates), decode at that
width, scatter only the live prefix back — slots outside the compact set
are never written.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models import make_caches


def _scatter_template(caches, template, idx):
    """Scatter the single-slot template into slots ``idx`` (int32 (n,)) of
    every leaf — the one definition of what 'reset' means."""
    n = idx.shape[0]
    return jax.tree.map(
        lambda x, t: x.at[:, idx].set(
            jnp.broadcast_to(t[:, :1], (t.shape[0], n) + t.shape[2:])),
        caches, template)


@functools.partial(jax.jit, donate_argnums=0)
def _reset_slots(caches, template, idx):
    """Reset slots in one fused scatter per leaf; the pool is donated so
    the scatter updates in place instead of copying all n_slots."""
    return _scatter_template(caches, template, idx)


@functools.partial(jax.jit, donate_argnums=0)
def _reset_and_view(caches, template, idx):
    """Fused reset-on-assign + batch view (gather): one device dispatch per
    acquire (vs an eager per-leaf allocation sweep in make_caches)."""
    caches = _scatter_template(caches, template, idx)
    view = jax.tree.map(lambda x: jnp.take(x, idx, axis=1), caches)
    return caches, view


@functools.partial(jax.jit, donate_argnums=0,
                   static_argnames=("lo", "n"))
def _reset_and_view_run(caches, template, *, lo, n):
    """Contiguous-slot fast path: reset via one dynamic_update_slice region
    and view via a static slice (no gather)."""
    caches = jax.tree.map(
        lambda x, t: jax.lax.dynamic_update_slice_in_dim(
            x, jnp.broadcast_to(t[:, :1], (t.shape[0], n) + t.shape[2:]),
            lo, axis=1),
        caches, template)
    view = jax.tree.map(
        lambda x: jax.lax.slice_in_dim(x, lo, lo + n, axis=1), caches)
    return caches, view


@functools.partial(jax.jit, donate_argnums=0)
def _write_slots(caches, batch, idx):
    return jax.tree.map(lambda x, b: x.at[:, idx].set(b), caches, batch)


@jax.jit
def _take_slots(caches, idx):
    """Fused batch-view gather: one device dispatch per view (vs an eager
    per-leaf ``jnp.take`` sweep), specializing on the slot *count* only —
    chunked prefill gathers its fill batch's staged slots every chunk, at
    arbitrary (fragmenting) offsets."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=1), caches)


@functools.partial(jax.jit, donate_argnums=0)
def _scatter_prefix(caches, batch, idx):
    """Scatter the first ``len(idx)`` rows of a (possibly wider) batch view
    back into pool slots ``idx`` — the compacted decode segment's
    write-back. The view may carry padding rows beyond the prefix (the
    occupancy-to-tier round-up); they are never written, so pool slots
    outside ``idx`` stay bitwise untouched. Specializes per
    (view width, slot count); the pool is donated so the scatter updates
    in place."""
    n = idx.shape[0]
    return jax.tree.map(
        lambda x, b: x.at[:, idx].set(jax.lax.slice_in_dim(b, 0, n, axis=1)),
        caches, batch)


class CachePool:
    def __init__(self, cfg, n_slots: int, max_len: int, *, long_ctx=False,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.caches = make_caches(cfg, n_slots, max_len, long_ctx=long_ctx,
                                  dtype=dtype)
        # single-slot template preserving per-leaf "empty" values (e.g. the
        # attention cache's pos = -1 sentinel)
        self._template = make_caches(cfg, 1, max_len, long_ctx=long_ctx,
                                     dtype=dtype)
        self.request_of = [None] * n_slots       # slot -> request id
        self.lengths = [0] * n_slots

    # ------------------------------------------------------- single slot
    def assign(self, request_id) -> int:
        return self.assign_many([request_id])[0]

    def release(self, slot: int) -> None:
        self.request_of[slot] = None
        self.lengths[slot] = 0

    # -------------------------------------------------------- batch slots
    def _claim(self, request_ids: Sequence) -> List[int]:
        """Book-keep one free slot per request; prefers a contiguous run so
        views can slice instead of gather."""
        ids = list(request_ids)
        free = [i for i, r in enumerate(self.request_of) if r is None]
        if len(ids) > len(free):
            raise RuntimeError(
                f"CachePool exhausted: {len(ids)} requested, "
                f"{len(free)} of {self.n_slots} slots free")
        slots = self._contiguous_run(free, len(ids)) or free[:len(ids)]
        for rid, s in zip(ids, slots):
            self.request_of[s] = rid
            self.lengths[s] = 0
        return slots

    def assign_many(self, request_ids: Sequence) -> List[int]:
        """Claim one slot per request and reset them all in a single fused
        device op (reset-on-assign)."""
        slots = self._claim(request_ids)
        self.caches = _reset_slots(self.caches, self._template,
                                   jnp.asarray(slots, jnp.int32))
        return slots

    @staticmethod
    def _contiguous_run(free: List[int], n: int) -> Optional[List[int]]:
        run: List[int] = []
        for s in free:
            if run and s == run[-1] + 1:
                run.append(s)
            else:
                run = [s]
            if len(run) == n:
                return run
        return None

    def acquire(self, request_ids: Sequence, *, gather: bool = False):
        """assign_many + batch_view in one fused device call — the engine's
        per-batch fast path. Returns (slots, batch_caches). Contiguous slot
        runs (the common case: whole batches release together) take the
        slice path; fragmented pools fall back to a gather. ``gather=True``
        forces the gather variant: its jit specializes only on the slot
        *count*, not the (lo, n) run position, so callers that acquire at
        arbitrary offsets mid-serve (the continuous scheduler's
        prefill-into-slot) compile one variant per batch size instead of
        one per run position."""
        slots = self._claim(request_ids)
        lo, n = slots[0], len(slots)
        if not gather and slots == list(range(lo, lo + n)):
            self.caches, view = _reset_and_view_run(
                self.caches, self._template, lo=lo, n=n)
        else:
            self.caches, view = _reset_and_view(
                self.caches, self._template, jnp.asarray(slots, jnp.int32))
        return slots, view

    def release_many(self, slots: Sequence[int]) -> None:
        for s in slots:
            self.release(s)

    def batch_view(self, slots: Sequence[int]):
        """Batch-sized cache pytree for the given slots (slot k of the view
        is pool slot slots[k]). Contiguous slots -> cheap slice; otherwise
        one fused jitted gather (compiled per slot count, not offsets)."""
        slots = list(slots)
        lo, n = slots[0], len(slots)
        if slots == list(range(lo, lo + n)):
            return jax.tree.map(
                lambda x: jax.lax.slice_in_dim(x, lo, lo + n, axis=1),
                self.caches)
        return _take_slots(self.caches, jnp.asarray(slots, jnp.int32))

    # ------------------------------------------- compacted decode segments
    def compact_view(self, slots: Sequence[int], width: int):
        """Tier-width cache view for a compacted decode segment: rows
        0..len(slots)-1 are the given slots (the live rows, in order); rows
        beyond are padding — duplicates of ``slots[0]`` that ride along
        inactive and are dropped by ``scatter_back``. Returns
        ``(idx, view)``: ``idx`` is the width-length gather order the view
        was built with, and callers must gather their per-row state by the
        same order — taking it from here (instead of re-deriving the
        padding convention) keeps cache rows and state rows structurally
        aligned. Always the fused ``_take_slots`` gather (never the
        contiguous-slice fast path), so jit specializes on ``width``
        alone: one compiled variant per tier, not per slot arrangement."""
        slots = list(slots)
        if not 0 < len(slots) <= width:
            raise ValueError(f"{len(slots)} slots do not fit width {width}")
        idx = slots + [slots[0]] * (width - len(slots))
        return idx, _take_slots(self.caches, jnp.asarray(idx, jnp.int32))

    def scatter_back(self, slots: Sequence[int], batch_caches,
                     lengths: Optional[Sequence[int]] = None) -> None:
        """Write a compacted segment's result back to the home slots: only
        the first ``len(slots)`` view rows land (padding rows are sliced
        away in-graph), so every slot outside ``slots`` — free, prefilling,
        or retired — keeps its KV bitwise. The counterpart of
        ``compact_view``; ``write_back`` stays the whole-view path."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.caches = _scatter_prefix(self.caches, batch_caches, idx)
        if lengths is not None:
            for s, n in zip(slots, lengths):
                self.lengths[s] = int(n)

    def write_back(self, slots: Sequence[int], batch_caches,
                   lengths: Optional[Sequence[int]] = None) -> None:
        """Store a batch view's (updated) caches back into the pool slots —
        the persistence hook for step-granularity continuous batching.
        Chunk-granular by design: chunked prefill calls this once per
        prompt chunk with the fill's staged caches and its partial
        ``lengths`` (tokens staged so far), so pool bookkeeping tracks
        prefill progress, not just completed prompts."""
        idx = jnp.asarray(list(slots), jnp.int32)
        self.caches = _write_slots(self.caches, batch_caches, idx)
        if lengths is not None:
            for s, n in zip(slots, lengths):
                self.lengths[s] = int(n)

    @property
    def free_slots(self) -> int:
        return self.request_of.count(None)
