"""KV-cache pool with request-slot management for continuous batching.

The cache pytree itself is built by ``models.make_caches`` (per-pattern
stacked ring buffers / recurrent states); this module adds the pool view the
engine uses: a fixed batch of slots, per-slot request ids and lengths, and
reset-on-assign semantics so a finished request's slot is immediately
reusable without reallocating device buffers.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import make_caches


class CachePool:
    def __init__(self, cfg, n_slots: int, max_len: int, *, long_ctx=False,
                 dtype=jnp.bfloat16):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.caches = make_caches(cfg, n_slots, max_len, long_ctx=long_ctx,
                                  dtype=dtype)
        # single-slot template preserving per-leaf "empty" values (e.g. the
        # attention cache's pos = -1 sentinel)
        self._template = make_caches(cfg, 1, max_len, long_ctx=long_ctx,
                                     dtype=dtype)
        self.request_of = [None] * n_slots       # slot -> request id
        self.lengths = [0] * n_slots

    def assign(self, request_id) -> int:
        slot = self.request_of.index(None)
        self.request_of[slot] = request_id
        self.lengths[slot] = 0
        self.caches = jax.tree.map(
            lambda x, t: x.at[:, slot].set(t[:, 0]), self.caches,
            self._template)
        return slot

    def release(self, slot: int) -> None:
        self.request_of[slot] = None
        self.lengths[slot] = 0

    @property
    def free_slots(self) -> int:
        return self.request_of.count(None)
