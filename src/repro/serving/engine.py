"""MLaaS serving engine — the JAX-native stand-in for the paper's
Nginx + Flask + GECToR stack (Fig. 6).

Two modes, matching the two model kinds in the repo:
  * 'encoder' — one bidirectional forward per request batch (GECToR: the
    paper's workload). Requests are token sequences; responses are the
    model's per-token outputs (edit tags for GECToR).
  * 'decoder' — prefill + autoregressive decode with a KV-cache pool
    (continuous batching at step granularity).

A background worker thread drains a request queue and forms batches (up to
``max_batch``, waiting at most ``batch_window_ms`` — the dynamic-batching
knob the paper's per-request Flask threading lacks). An optional
``AdmissionQueue`` bounds in-flight work (the paper's proposed §4
mitigation): submit() try-acquires a slot and, when saturated, parks the
request on an overflow deque; a finishing request hands its slot straight
to the next parked one. submit() never blocks and no dispatcher thread is
spawned per request (the old design's unbounded thread creation under
load). Per-request wall latency and batch stats are recorded so the
load-test client can tabulate the paper's metrics.

Decoder hot path: prefill + first-token selection + the remaining
``max_new_tokens - 1`` greedy steps are fused into a single jitted function
(``models.decode_loop`` runs the steps as one ``jax.lax.scan``), so a batch
costs one dispatch and one host sync instead of a Python round-trip per
token. KV caches come from per-bucket ``CachePool``s — persistent device
slots reset on assignment — instead of a fresh ``make_caches`` allocation
sweep per batch. Both optimizations can be disabled (``use_scan_decode`` /
``use_cache_pool``) to reproduce the legacy per-token path for A/B
benchmarks and equivalence tests.
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (decode_loop, decode_step, forward, make_caches)
from repro.serving.kvcache import CachePool
from repro.serving.scheduler import AdmissionQueue


class RequestTooLong(ValueError):
    """Raised (into the request's future) when a request exceeds the largest
    pad bucket — rejecting beats the silent truncation it replaces."""


@dataclasses.dataclass
class EngineConfig:
    mode: str = "encoder"             # 'encoder' | 'decoder'
    max_batch: int = 32
    batch_window_ms: float = 2.0
    pad_buckets: tuple = (32, 64, 128, 256, 512)
    max_inflight: Optional[int] = None   # admission control; None = off
    max_new_tokens: int = 16             # decoder mode
    use_scan_decode: bool = True         # fused lax.scan decode hot path
    use_cache_pool: bool = True          # pooled KV slots vs per-batch alloc


@dataclasses.dataclass
class _Request:
    tokens: np.ndarray
    future: Future
    t_submit: float


class ServingEngine:
    def __init__(self, cfg, params, engine_cfg: EngineConfig,
                 head_fn: Optional[Callable] = None):
        """head_fn(hidden (B,S,d)) -> per-request payload; defaults to
        hidden states (encoder) / sampled tokens (decoder)."""
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.head_fn = head_fn
        self._q: "queue.Queue[_Request]" = queue.Queue()
        self._admission = (AdmissionQueue(engine_cfg.max_inflight)
                           if engine_cfg.max_inflight else None)
        self.latencies: List[float] = []
        self.batch_sizes: List[int] = []
        self._stop = threading.Event()
        # reentrant: a done-callback attached under the lock can fire
        # synchronously (future cancelled in the attach window) and re-enter
        self._submit_lock = threading.RLock()  # orders submit vs close
        self._overflow = collections.deque()   # admission overflow queue
        self._compiled = {}
        self._pools = {}                  # bucket -> CachePool
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- client
    def submit(self, tokens: np.ndarray) -> Future:
        fut: Future = Future()
        toks = np.asarray(tokens, np.int32)
        if self._stop.is_set():
            fut.set_exception(RuntimeError("engine is closed"))
            return fut
        try:
            self._bucket(len(toks))
        except RequestTooLong as e:
            fut.set_exception(e)
            return fut
        req = _Request(toks, fut, time.perf_counter())
        if self._admission is not None:
            with self._submit_lock:
                if self._stop.is_set():
                    fut.set_exception(RuntimeError("engine is closed"))
                    return fut
                if self._admission.try_acquire():
                    self._enqueue_admitted(req)
                else:
                    # saturated: park without blocking the submitter; a
                    # finishing request's done-callback transfers its slot
                    self._overflow.append(req)
                    self._admission.note_queued(len(self._overflow))
            return fut
        # the lock orders this enqueue against close()'s drain: either the
        # request lands before the drain (and is failed by it) or it sees
        # _stop and is rejected here — it can never be silently stranded
        with self._submit_lock:
            if self._stop.is_set():
                fut.set_exception(RuntimeError("engine is closed"))
                return fut
            self._q.put(req)
        return fut

    def _enqueue_admitted(self, req: _Request) -> None:
        """Put an admitted request on the worker queue; its slot is held
        until the future resolves, then handed to the next parked request.
        Caller holds _submit_lock. If the future is already done (a cancel
        won a race), add_done_callback fires synchronously in this thread —
        safe because _submit_lock is reentrant."""
        req.future.add_done_callback(self._on_admitted_done)
        self._q.put(req)

    def _on_admitted_done(self, _fut) -> None:
        with self._submit_lock:
            while self._overflow and not self._stop.is_set():
                nxt = self._overflow.popleft()
                if nxt.future.done():      # cancelled while parked: it
                    continue               # holds no slot; try the next
                self._admission.admit_transfer(
                    time.perf_counter() - nxt.t_submit)
                self._enqueue_admitted(nxt)
                return
            self._admission.release()

    def close(self):
        self._stop.set()
        self._worker.join(timeout=5)
        # fail everything still parked or queued: resolves client futures
        # (and, via the done-callbacks, frees any held admission slots)
        with self._submit_lock:
            pending = list(self._overflow)
            self._overflow.clear()
        while True:
            try:
                pending.append(self._q.get_nowait())
            except queue.Empty:
                break
        for req in pending:
            if not req.future.done():
                req.future.set_exception(RuntimeError("engine is closed"))

    # ------------------------------------------------------------- server
    def _bucket(self, n: int) -> int:
        for b in self.ec.pad_buckets:
            if n <= b:
                return b
        raise RequestTooLong(
            f"request of {n} tokens exceeds the largest pad bucket "
            f"({self.ec.pad_buckets[-1]}); split the request or configure "
            f"larger pad_buckets")

    def _encoder_fn(self, bucket: int):
        if ("enc", bucket) not in self._compiled:
            def fn(params, tokens, mask):
                pos = jnp.broadcast_to(
                    jnp.arange(tokens.shape[1], dtype=jnp.int32),
                    tokens.shape)
                # GECToR-style param trees nest the encoder under 'encoder'
                enc_params = params.get("encoder", params)
                hid, _, _ = forward(self.cfg, enc_params, tokens=tokens,
                                    positions=pos, causal=False,
                                    return_hidden=True)
                if self.head_fn is not None:
                    return self.head_fn(params, hid, mask)
                return hid
            self._compiled[("enc", bucket)] = jax.jit(fn)
        return self._compiled[("enc", bucket)]

    # --------------------------------------------------- decoder hot path
    def _decode_scan_fn(self):
        """One fused jitted function: prefill -> per-row last-position
        argmax -> scan over the remaining steps. jit specializes it per
        (batch, bucket) shape; one dispatch serves the whole batch."""
        if "dec_scan" not in self._compiled:
            T = self.ec.max_new_tokens

            def fn(params, toks, lens, caches):
                logits, caches, _ = forward(self.cfg, params, tokens=toks,
                                            caches=caches, mode="full")
                # first generated token: per-row logits at the row's real
                # last position (padded rows must not sample from garbage)
                last = jnp.take_along_axis(
                    logits, (lens - 1)[:, None, None], axis=1)
                tok = jnp.argmax(last[:, 0], axis=-1)[:, None]
                tok = tok.astype(jnp.int32)
                if T == 1:
                    return tok, caches
                rest, caches = decode_loop(self.cfg, params, tok,
                                           lens[:, None], caches,
                                           n_steps=T - 1)
                return jnp.concatenate([tok, rest], axis=1), caches

            self._compiled["dec_scan"] = jax.jit(fn)
        return self._compiled["dec_scan"]

    def _decode_fns(self):
        """Legacy per-token path (kept for A/B benchmarks + equivalence
        tests; ``use_scan_decode=False`` selects it). unroll_periods=False
        reproduces the seed's scanned-period step structure exactly."""
        if "dec" not in self._compiled:
            self._compiled["dec"] = (
                jax.jit(lambda p, t, c: forward(self.cfg, p, tokens=t,
                                                caches=c, mode="full")),
                jax.jit(lambda p, t, pos, c: decode_step(
                    self.cfg, p, t, pos, c, unroll_periods=False)),
            )
        return self._compiled["dec"]

    def _acquire_caches(self, B: int, bucket: int):
        """Batch-sized decode caches: pooled slots (reset-on-assign, no
        per-batch allocation sweep) or a fresh make_caches tree."""
        L = bucket + self.ec.max_new_tokens
        if not self.ec.use_cache_pool:
            return make_caches(self.cfg, B, L, dtype=jnp.float32), None
        pool = self._pools.get(bucket)
        if pool is None:
            pool = CachePool(self.cfg, self.ec.max_batch, L,
                             dtype=jnp.float32)
            self._pools[bucket] = pool
        slots, view = pool.acquire([f"b{bucket}.{i}" for i in range(B)])
        return view, (pool, slots)

    @staticmethod
    def _release_caches(handle):
        if handle is not None:
            pool, slots = handle
            pool.release_many(slots)

    def _serve_decoder(self, toks, lens, bucket):
        B = len(lens)
        lens_a = jnp.asarray(np.array(lens, np.int32))
        caches, handle = self._acquire_caches(B, bucket)
        try:
            if self.ec.use_scan_decode:
                gen, _ = self._decode_scan_fn()(
                    self.params, jnp.asarray(toks), lens_a, caches)
                return np.asarray(gen)
            prefill_fn, step_fn = self._decode_fns()
            logits, caches, _ = prefill_fn(self.params, jnp.asarray(toks),
                                           caches)
            last = jnp.take_along_axis(
                logits, (lens_a - 1)[:, None, None], axis=1)
            tok = jnp.argmax(last[:, 0], axis=-1)[:, None].astype(jnp.int32)
            outs = [np.asarray(tok)]
            pos = lens_a[:, None] - 1
            for _ in range(self.ec.max_new_tokens - 1):
                pos = pos + 1
                logits, caches, _ = step_fn(self.params, tok, pos, caches)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                outs.append(np.asarray(tok))
            return np.concatenate(outs, axis=1)
        finally:
            self._release_caches(handle)

    def _serve_batch(self, reqs: List[_Request]):
        # claim each future (concurrent.futures protocol): a client-side
        # cancel() that won between enqueue and here drops the request
        # instead of poisoning set_result for the whole batch
        reqs = [r for r in reqs if r.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        lens = [len(r.tokens) for r in reqs]
        bucket = self._bucket(max(lens))
        B = len(reqs)
        toks = np.zeros((B, bucket), np.int32)
        mask = np.zeros((B, bucket), bool)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
            mask[i, :len(r.tokens)] = True

        if self.ec.mode == "encoder":
            out = self._encoder_fn(bucket)(self.params, jnp.asarray(toks),
                                           jnp.asarray(mask))
            out = jax.device_get(out)
            for i, r in enumerate(reqs):
                r.future.set_result(jax.tree.map(lambda x: x[i], out))
        else:
            gen = self._serve_decoder(toks, lens, bucket)
            for i, r in enumerate(reqs):
                r.future.set_result(gen[i])

        now = time.perf_counter()
        self.batch_sizes.append(B)
        for r in reqs:
            self.latencies.append(now - r.t_submit)

    def _run(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.ec.batch_window_ms / 1e3
            while len(batch) < self.ec.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            try:
                self._serve_batch(batch)
            except Exception as e:  # pragma: no cover - surfaced to client
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)

    # ------------------------------------------------------------ metrics
    def metrics(self) -> dict:
        lat = np.array(self.latencies) if self.latencies else np.zeros(1)
        m = {"requests": len(self.latencies),
             "latency_mean_s": float(lat.mean()),
             "latency_p50_s": float(np.percentile(lat, 50)),
             "latency_p95_s": float(np.percentile(lat, 95)),
             "batch_size_mean": float(np.mean(self.batch_sizes))
             if self.batch_sizes else 0.0}
        if self._admission is not None:
            m["admission_peak_queue"] = self._admission.stats.queued_peak
            m["admission_wait_total_s"] = self._admission.stats.wait_total_s
        return m
