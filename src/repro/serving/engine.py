"""MLaaS serving engine — the JAX-native stand-in for the paper's
Nginx + Flask + GECToR stack (Fig. 6).

Two modes, matching the two model kinds in the repo:
  * 'encoder' — one bidirectional forward per request batch (GECToR: the
    paper's workload). Requests are token sequences; responses are the
    model's per-token outputs (edit tags for GECToR).
  * 'decoder' — prefill + autoregressive decode with a KV-cache pool
    (continuous batching at step granularity).

A background worker thread drains a request queue and forms batches (up to
``max_batch``, waiting at most ``batch_window_ms`` — the dynamic-batching
knob the paper's per-request Flask threading lacks). An optional
``AdmissionQueue`` bounds in-flight work (the paper's proposed §4
mitigation). Per-request wall latency and batch stats are recorded so the
load-test client can tabulate the paper's metrics.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, forward, make_caches
from repro.serving.scheduler import AdmissionQueue


@dataclasses.dataclass
class EngineConfig:
    mode: str = "encoder"             # 'encoder' | 'decoder'
    max_batch: int = 32
    batch_window_ms: float = 2.0
    pad_buckets: tuple = (32, 64, 128, 256, 512)
    max_inflight: Optional[int] = None   # admission control; None = off
    max_new_tokens: int = 16             # decoder mode


@dataclasses.dataclass
class _Request:
    tokens: np.ndarray
    future: Future
    t_submit: float


class ServingEngine:
    def __init__(self, cfg, params, engine_cfg: EngineConfig,
                 head_fn: Optional[Callable] = None):
        """head_fn(hidden (B,S,d)) -> per-request payload; defaults to
        hidden states (encoder) / sampled tokens (decoder)."""
        self.cfg = cfg
        self.params = params
        self.ec = engine_cfg
        self.head_fn = head_fn
        self._q: "queue.Queue[_Request]" = queue.Queue()
        self._admission = (AdmissionQueue(engine_cfg.max_inflight)
                           if engine_cfg.max_inflight else None)
        self.latencies: List[float] = []
        self.batch_sizes: List[int] = []
        self._stop = threading.Event()
        self._compiled = {}
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- client
    def submit(self, tokens: np.ndarray) -> Future:
        fut: Future = Future()
        req = _Request(np.asarray(tokens, np.int32), fut, time.perf_counter())
        if self._admission is not None:
            def admit():
                with self._admission:
                    self._q.put(req)
                    req.future.result()  # hold the slot until served
            threading.Thread(target=admit, daemon=True).start()
        else:
            self._q.put(req)
        return fut

    def close(self):
        self._stop.set()
        self._worker.join(timeout=5)

    # ------------------------------------------------------------- server
    def _bucket(self, n: int) -> int:
        for b in self.ec.pad_buckets:
            if n <= b:
                return b
        return self.ec.pad_buckets[-1]

    def _encoder_fn(self, bucket: int):
        if ("enc", bucket) not in self._compiled:
            def fn(params, tokens, mask):
                pos = jnp.broadcast_to(
                    jnp.arange(tokens.shape[1], dtype=jnp.int32),
                    tokens.shape)
                # GECToR-style param trees nest the encoder under 'encoder'
                enc_params = params.get("encoder", params)
                hid, _, _ = forward(self.cfg, enc_params, tokens=tokens,
                                    positions=pos, causal=False,
                                    return_hidden=True)
                if self.head_fn is not None:
                    return self.head_fn(params, hid, mask)
                return hid
            self._compiled[("enc", bucket)] = jax.jit(fn)
        return self._compiled[("enc", bucket)]

    def _decode_fns(self):
        if "dec" not in self._compiled:
            self._compiled["dec"] = (
                jax.jit(lambda p, t, c: forward(self.cfg, p, tokens=t,
                                                caches=c, mode="full")),
                jax.jit(lambda p, t, pos, c: decode_step(self.cfg, p, t, pos,
                                                         c)),
            )
        return self._compiled["dec"]

    def _serve_batch(self, reqs: List[_Request]):
        lens = [len(r.tokens) for r in reqs]
        bucket = self._bucket(max(lens))
        B = len(reqs)
        toks = np.zeros((B, bucket), np.int32)
        mask = np.zeros((B, bucket), bool)
        for i, r in enumerate(reqs):
            L = min(len(r.tokens), bucket)
            toks[i, :L] = r.tokens[:L]
            mask[i, :L] = True

        if self.ec.mode == "encoder":
            out = self._encoder_fn(bucket)(self.params, jnp.asarray(toks),
                                           jnp.asarray(mask))
            out = jax.device_get(out)
            for i, r in enumerate(reqs):
                r.future.set_result(jax.tree.map(lambda x: x[i], out))
        else:
            prefill_fn, step_fn = self._decode_fns()
            caches = make_caches(self.cfg, B, bucket + self.ec.max_new_tokens,
                                 dtype=jnp.float32)
            logits, caches, _ = prefill_fn(self.params, jnp.asarray(toks),
                                           caches)
            # first generated token: per-row logits at the row's real last
            # position (padded rows must not sample from garbage columns)
            lens_a = jnp.asarray(np.array(lens, np.int32))
            last = jnp.take_along_axis(
                logits, (lens_a - 1)[:, None, None], axis=1)
            tok = jnp.argmax(last[:, 0], axis=-1)[:, None].astype(jnp.int32)
            outs = [np.asarray(tok)]
            pos = lens_a[:, None] - 1
            for _ in range(self.ec.max_new_tokens - 1):
                pos = pos + 1
                logits, caches, _ = step_fn(self.params, tok, pos, caches)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                outs.append(np.asarray(tok))
            gen = np.concatenate(outs, axis=1)
            for i, r in enumerate(reqs):
                r.future.set_result(gen[i])

        now = time.perf_counter()
        self.batch_sizes.append(B)
        for r in reqs:
            self.latencies.append(now - r.t_submit)

    def _run(self):
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.ec.batch_window_ms / 1e3
            while len(batch) < self.ec.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            try:
                self._serve_batch(batch)
            except Exception as e:  # pragma: no cover - surfaced to client
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)

    # ------------------------------------------------------------ metrics
    def metrics(self) -> dict:
        lat = np.array(self.latencies) if self.latencies else np.zeros(1)
        m = {"requests": len(self.latencies),
             "latency_mean_s": float(lat.mean()),
             "latency_p50_s": float(np.percentile(lat, 50)),
             "latency_p95_s": float(np.percentile(lat, 95)),
             "batch_size_mean": float(np.mean(self.batch_sizes))
             if self.batch_sizes else 0.0}
        if self._admission is not None:
            m["admission_peak_queue"] = self._admission.stats.queued_peak
            m["admission_wait_total_s"] = self._admission.stats.wait_total_s
        return m
