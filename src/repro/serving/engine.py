"""MLaaS serving engine — the JAX-native stand-in for the paper's
Nginx + Flask + GECToR stack (Fig. 6).

Two modes, matching the two model kinds in the repo:
  * 'encoder' — one bidirectional forward per request batch (GECToR: the
    paper's workload). Requests are token sequences; responses are the
    model's per-token outputs (edit tags for GECToR).
  * 'decoder' — prefill + autoregressive decode with a KV-cache pool.

Decoder requests go through the typed v2 lifecycle (``serving.api``):
``engine.generate(GenerationRequest | tokens)`` returns a
``RequestHandle`` (streaming iterator + future) that resolves to a
``GenerationResult`` (tokens, finish_reason, per-phase timing). The default
decoder worker is the step-driven continuous scheduler
(``serving.continuous``): decode runs in short jitted scan segments over a
fixed slot batch; between segments finished rows retire (per-row eos /
max_new_tokens stop in-graph, see ``models.decode_segment``) and newly
admitted requests prefill straight into free ``CachePool`` slots — a
request submitted mid-decode joins the in-flight batch instead of waiting
behind it. ``continuous=False`` keeps the PR-1 batch-at-a-time worker for
A/B equivalence runs: a background thread drains the queue and forms
batches (up to ``max_batch``, waiting at most ``batch_window_ms``), serving
prefill + first-token + the remaining steps as one jitted
``models.decode_segment`` call (``use_scan_decode=False`` further falls
back to the seed's per-token Python loop).

An optional ``AdmissionQueue`` bounds in-flight work (the paper's proposed
§4 mitigation): submit try-acquires a slot and, when saturated, parks the
request on a priority-ordered overflow queue; a finishing request hands its
slot to the best parked one. Submission never blocks and no dispatcher
thread is spawned per request. Per-request wall latency, per-phase timing,
and batch-occupancy stats are recorded so the load-test client can tabulate
the paper's metrics — and the per-phase split it cannot see.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (decode_segment, decode_step, forward, make_caches,
                          prefill_chunk, sample_logits, spec_round)
from repro.quant import params_bytes, quantize_params, validate_kv_quant
from repro.serving.api import (FINISH_CANCELLED, FINISH_EOS, FINISH_LENGTH,
                               GenerationRequest, GenerationResult, HeadFn,
                               RequestHandle, RequestTiming, SamplingParams)
from repro.serving import kvcache
from repro.serving.kvcache import CachePool, _take_slots
from repro.serving.scheduler import (AdmissionQueue, RequestQueue,
                                     pick_tier, width_tiers)


class RequestTooLong(ValueError):
    """Raised (into the request's future) when a request exceeds the largest
    pad bucket — rejecting beats the silent truncation it replaces."""


@dataclasses.dataclass
class EngineConfig:
    mode: str = "encoder"             # 'encoder' | 'decoder'
    max_batch: int = 32
    batch_window_ms: float = 2.0
    pad_buckets: tuple = (32, 64, 128, 256, 512)
    max_inflight: Optional[int] = None   # admission control; None = off
    max_new_tokens: int = 16             # decoder: per-request budget cap
    use_scan_decode: bool = True         # fused lax.scan decode hot path
    use_cache_pool: bool = True          # pooled KV slots vs per-batch alloc
    # step-level continuous batching (decoder mode; requires scan + pool —
    # otherwise the engine falls back to the batch-at-a-time worker).
    # False = batch-at-a-time, kept for A/B equivalence runs.
    continuous: bool = True
    decode_segment: int = 4              # decode steps per jitted segment
    # per-bucket lanes: requests admit into their own bucket's slot set
    # immediately instead of waiting for another bucket's set to drain.
    # False = legacy single-set admission gate, kept for A/B runs
    # (bench_multi_bucket's baseline).
    multi_lane: bool = True
    # chunked prefill: a join whose prompt exceeds this many tokens
    # prefills in chunks of this size, interleaved with decode segments,
    # instead of stalling every in-flight row for the whole prompt's
    # forward. None = whole-prompt prefill (token-identical either way).
    prefill_chunk: Optional[int] = None
    # occupancy-adaptive decode segments: 'adaptive' compacts each lane's
    # live rows into the smallest width tier (powers of two up to
    # max_batch) before every segment, so a lane at occupancy 1 decodes at
    # width 1 instead of max_batch; 'fixed' keeps the full-width segment,
    # the A/B baseline (bench_segment_width). Token-identical either way.
    segment_width: str = "adaptive"
    # prefix cache: store completed prompts' KV at prefill_chunk-granular
    # boundaries; a joining request sharing a stored prefix copies it into
    # its slot (one fused gather/scatter) and prefills only the suffix.
    # Requires the continuous path + prefill_chunk, and a pure
    # global-attention pattern (no sliding-window rings / recurrent state
    # — those cannot be replayed at an absolute offset). Token-identical
    # to the cold path either way.
    prefix_cache: bool = False
    # per-bucket byte budget for stored prefix KV; None sizes the store to
    # max_batch slots' worth (LRU eviction keeps it under budget)
    prefix_cache_bytes: Optional[int] = None
    # weight quantization: "int8" quantizes the matmul layer classes
    # (attn projections + MLP; see quant/policy.py) to symmetric
    # per-channel int8 at engine init — projections then run the
    # dequant-fused matmul with no stored float weight copy. None (the
    # default) keeps the bf16 path bit-identical.
    weight_quant: Optional[str] = None
    # KV-cache quantization: "int8" stores pool slots as int8 K/V with
    # per-(position, head) f32 scale planes — quantize at scatter,
    # dequantize at gather; lanes, width tiers and the prefix cache carry
    # the scale planes unchanged. Decoder mode only.
    kv_quant: Optional[str] = None
    # speculative decoding: each scheduler turn a small draft model
    # proposes spec_k tokens per row and the target verifies all of them
    # in one fused forward, committing the leading agreements plus one
    # target-selected token (>= 1 token/round/row). Requires the
    # continuous path, a pure global-attention pattern on both models,
    # and a ``draft=(draft_cfg, draft_params)`` pair at engine
    # construction. Token-identical to plain decode, greedy or sampled.
    spec_decode: bool = False
    # draft tokens proposed per round; the verify chunk covers
    # spec_k + 1 positions, so each slot carries spec_k positions of ring
    # headroom beyond bucket + max_new_tokens
    spec_k: int = 4


@dataclasses.dataclass
class _Request:
    """Internal carrier. Legacy paths (encoder mode, raw benchmarks) build
    it with the three positional fields; v2 decoder requests also carry
    sampling params, priority, and the client handle."""
    tokens: np.ndarray
    future: Future
    t_submit: float
    sampling: Optional[SamplingParams] = None
    budget: int = 0                   # effective max_new_tokens
    priority: int = 0
    handle: Optional[RequestHandle] = None
    t_start: float = 0.0              # worker picked it up (prefill start)
    t_prefill_done: float = 0.0


def _trim_host(gen: np.ndarray, eos: np.ndarray, budget: np.ndarray):
    """Host-side emission trim for the batch-at-a-time path: a row's output
    ends at its budget or just after its first eos token. Token-identical
    to the in-graph retirement the continuous path does (sampling is
    counter-based per position, so tokens after a row's stop point never
    influence the kept prefix)."""
    B, T = gen.shape
    emits = np.zeros((B, T), bool)
    eos_hit = np.zeros(B, bool)
    for i in range(B):
        n = int(min(budget[i], T))
        if eos[i] >= 0:
            where = np.where(gen[i, :n] == eos[i])[0]
            if where.size:
                n = int(where[0]) + 1
                eos_hit[i] = True
        emits[i, :n] = True
    return emits, eos_hit


class ServingEngine:
    def __init__(self, cfg, params, engine_cfg: EngineConfig,
                 head_fn: Optional[HeadFn] = None, draft=None):
        """``head_fn(params, hidden, mask)`` — see ``serving.api.HeadFn``:
        called inside the jitted encoder function with the full parameter
        tree, final hidden states (B, S, d_model) and the validity mask
        (B, S); returns the per-request payload. Defaults to hidden states
        (encoder) / generated tokens (decoder). ``draft`` is the
        ``(draft_cfg, draft_params)`` pair speculative decoding proposes
        with (required iff ``spec_decode`` is on)."""
        self.cfg = cfg                    # guarded-by: init
        self.params = params              # guarded-by: init
        self.ec = engine_cfg              # guarded-by: init
        self.head_fn = head_fn            # guarded-by: init
        self.draft_cfg, self.draft_params = draft or (None, None)  # guarded-by: init
        if engine_cfg.weight_quant not in (None, "int8"):
            raise ValueError(f"weight_quant must be None or 'int8', got "
                             f"{engine_cfg.weight_quant!r}")
        validate_kv_quant(engine_cfg.kv_quant)
        if engine_cfg.kv_quant and engine_cfg.mode != "decoder":
            raise ValueError("kv_quant requires mode='decoder' (the KV "
                             "cache only exists on the decode path)")
        if engine_cfg.weight_quant == "int8":
            # one-time at init: the matmul layer classes go int8 (policy in
            # quant/policy.py); everything downstream — warmup, jitted
            # prefill/segments — traces against the quantized tree, so the
            # measured windows stay compile-clean with no extra priming
            self.params = quantize_params(self.params)
        self._weight_bytes = params_bytes(self.params)   # guarded-by: init
        self._q: "queue.Queue[_Request]" = queue.Queue()  # guarded-by: threadsafe
        self._admission = (AdmissionQueue(engine_cfg.max_inflight)  # guarded-by: threadsafe
                           if engine_cfg.max_inflight else None)
        self.latencies: List[float] = []          # guarded-by: worker
        self.batch_sizes: List[int] = []          # guarded-by: worker
        self.timings: List[RequestTiming] = []    # guarded-by: worker — v2 per-phase breakdowns
        self._stats = {"decode_segments": 0,      # guarded-by: worker
                       "joins_mid_flight": 0,
                       "prefill_batches": 0, "prefill_chunks": 0}
        self.lane_stats = {}              # guarded-by: worker — per-lane counters
        # window() cursors: list lengths + counter values at the last snap
        self._win_cursor = {"latencies": 0,       # guarded-by: client
                            "batch_sizes": 0, "timings": 0,
                            "stats": dict(self._stats), "lanes": {}}
        self._stop = threading.Event()            # guarded-by: threadsafe
        # reentrant: a done-callback attached under the lock can fire
        # synchronously (future cancelled in the attach window) and re-enter
        self._submit_lock = threading.RLock()  # guarded-by: threadsafe — orders submit vs close
        self._overflow = RequestQueue()        # guarded-by: _submit_lock — admission overflow
        self._parked_cancelled = 0             # guarded-by: _submit_lock — phantoms in heap
        self._compiled = {}               # guarded-by: worker
        self._pools = {}                  # guarded-by: worker — bucket -> CachePool
        self.continuous_active = (        # guarded-by: init
            engine_cfg.mode == "decoder" and engine_cfg.continuous
            and engine_cfg.use_scan_decode and engine_cfg.use_cache_pool)
        if engine_cfg.segment_width not in ("adaptive", "fixed"):
            raise ValueError(
                f"segment_width must be 'adaptive' or 'fixed', got "
                f"{engine_cfg.segment_width!r}")
        # the width ladder compacted segments may run at (see scheduler.
        # width_tiers); 'fixed' degenerates to the max_batch-only ladder
        self._tiers = (width_tiers(engine_cfg.max_batch)  # guarded-by: init
                       if engine_cfg.segment_width == "adaptive"
                       else (engine_cfg.max_batch,))
        C = engine_cfg.prefill_chunk
        if self.continuous_active and C is not None:
            if C < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got {C}")
            for b in engine_cfg.pad_buckets:
                # the final chunk is padded up to a multiple of C; those
                # padded positions land in the slot's KV ring and must not
                # wrap past its length (bucket + max_new_tokens), or they
                # would silently overwrite the prompt prefix's KV
                if -(-b // C) * C > b + engine_cfg.max_new_tokens:
                    raise ValueError(
                        f"prefill_chunk={C} rounds bucket {b} prompts up "
                        f"to {-(-b // C) * C} cache positions, past the "
                        f"slot's {b + engine_cfg.max_new_tokens}; pick a "
                        f"chunk dividing the bucket or raise "
                        f"max_new_tokens")
        self._prefix_stores = {}          # guarded-by: worker — bucket -> PrefixStore
        if engine_cfg.prefix_cache:
            if not self.continuous_active:
                raise ValueError(
                    "prefix_cache requires the continuous decoder path "
                    "(mode='decoder', continuous/use_scan_decode/"
                    "use_cache_pool all on)")
            if C is None:
                raise ValueError(
                    "prefix_cache requires prefill_chunk: chunk boundaries "
                    "define the prefix granularity")
            bad = [k for k in cfg.pattern if k not in ("attn", "attn_global")]
            if bad or getattr(cfg, "enc_layers", 0):
                raise ValueError(
                    f"prefix_cache requires a pure global-attention "
                    f"pattern: sliding-window rings and recurrent states "
                    f"cannot be replayed at an absolute KV offset "
                    f"(pattern={cfg.pattern!r})")
        self._draft_pools = {}            # guarded-by: worker — bucket -> draft CachePool
        if engine_cfg.spec_decode:
            if not self.continuous_active:
                raise ValueError(
                    "spec_decode requires the continuous decoder path "
                    "(mode='decoder', continuous/use_scan_decode/"
                    "use_cache_pool all on)")
            if engine_cfg.spec_k < 1:
                raise ValueError(
                    f"spec_k must be >= 1, got {engine_cfg.spec_k}")
            if self.draft_cfg is None or self.draft_params is None:
                raise ValueError(
                    "spec_decode requires draft=(draft_cfg, draft_params) "
                    "at engine construction")
            for role, c in (("target", cfg), ("draft", self.draft_cfg)):
                bad = [k for k in c.pattern
                       if k not in ("attn", "attn_global")]
                if bad or getattr(c, "enc_layers", 0):
                    raise ValueError(
                        f"spec_decode requires a pure global-attention "
                        f"{role} pattern: per-row KV rollback cannot "
                        f"rewind sliding-window rings or recurrent state "
                        f"(pattern={c.pattern!r})")
            if self.draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab_size {self.draft_cfg.vocab_size} != "
                    f"target {cfg.vocab_size}: proposed ids must be "
                    f"scoreable by the target")
        if self.continuous_active:
            for b in engine_cfg.pad_buckets:
                self._lane_stat(b)   # fixed key set: metrics() iterates
                                     # lane_stats without a lock
            from repro.serving.continuous import ContinuousScheduler
            self._scheduler = ContinuousScheduler(self)  # guarded-by: init
            target = self._scheduler.run
        else:
            target = self._run
        self._worker = threading.Thread(target=target, daemon=True)  # guarded-by: init
        self._worker.start()

    # ------------------------------------------------------------- client
    def generate(self, request, sampling: Optional[SamplingParams] = None,
                 *, priority: int = 0,
                 request_id: Optional[str] = None) -> RequestHandle:
        """Submit a typed generation request (decoder mode).

        ``request`` is a ``GenerationRequest`` or a raw token array (then
        ``sampling``/``priority``/``request_id`` build one). Returns a
        ``RequestHandle`` immediately; validation errors (``RequestTooLong``,
        bad sampling params) resolve the handle's future exceptionally
        rather than raising here, so submission never throws mid-burst.
        """
        if self.ec.mode != "decoder":
            raise ValueError("generate() requires mode='decoder'; encoder "
                             "mode serves via submit()")
        if not isinstance(request, GenerationRequest):
            request = GenerationRequest(
                tokens=np.asarray(request, np.int32),
                sampling=sampling or SamplingParams(),
                priority=priority, request_id=request_id)
        fut: Future = Future()
        handle = RequestHandle(request, fut)
        toks = np.asarray(request.tokens, np.int32)
        try:
            if self._stop.is_set():
                raise RuntimeError("engine is closed")
            if toks.ndim != 1 or toks.size < 1:
                # an empty prompt would flow lens - 1 == -1 into the
                # prefill's take_along_axis, wrapping to the last padded
                # position — the first token would sample from garbage
                raise ValueError(
                    f"prompt must be a non-empty 1-D token sequence, got "
                    f"shape {toks.shape}")
            budget = request.sampling.validate(self.ec.max_new_tokens)
            if (request.sampling.temperature > 0
                    and not self.ec.use_scan_decode):
                raise ValueError("sampling (temperature > 0) requires "
                                 "use_scan_decode=True")
            self._bucket(len(toks))
        except Exception as e:  # surfaced through the handle
            fut.set_exception(e)
            return handle
        req = _Request(toks, fut, time.perf_counter(),
                       sampling=request.sampling, budget=budget,
                       priority=request.priority, handle=handle)
        self._submit_req(req)
        return handle

    def submit(self, tokens: np.ndarray) -> Future:
        """v1 shim, kept for the seed API: untyped tokens in, future out.

        Encoder mode: unchanged. Decoder mode: deprecated — delegates to
        ``generate()`` with default (greedy) ``SamplingParams`` and returns
        a future resolving to the bare token array; cancelling the returned
        future does not cancel the underlying request (use the handle API).
        """
        if self.ec.mode == "decoder":
            h = self.generate(tokens)
            out: Future = Future()

            def relay(f):
                if f.cancelled():
                    out.cancel()
                elif f.exception() is not None:
                    out.set_exception(f.exception())
                else:
                    out.set_result(f.result().tokens)

            h.future.add_done_callback(relay)
            return out
        fut: Future = Future()
        toks = np.asarray(tokens, np.int32)
        if self._stop.is_set():
            fut.set_exception(RuntimeError("engine is closed"))
            return fut
        try:
            self._bucket(len(toks))
        except RequestTooLong as e:
            fut.set_exception(e)
            return fut
        self._submit_req(_Request(toks, fut, time.perf_counter()))
        return fut

    def _submit_req(self, req: _Request) -> None:
        """Admission + enqueue, shared by submit() and generate()."""
        if self._admission is not None:
            with self._submit_lock:
                if self._stop.is_set():
                    req.future.set_exception(RuntimeError("engine is closed"))
                    return
                if self._admission.try_acquire():
                    self._enqueue_admitted(req)
                else:
                    # saturated: park without blocking the submitter; a
                    # finishing request's done-callback transfers its slot
                    # to the best-priority parked request. The reported
                    # depth excludes requests cancelled while parked
                    # (they sit in the heap until a pop scans past them,
                    # but wait for nothing): a cancelled future can only
                    # have been parked — running ones refuse cancel — so
                    # a done-callback counts them in O(1) per submit
                    self._overflow.push(req, req.priority)
                    req.future.add_done_callback(self._on_parked_done)
                    self._admission.note_queued(
                        len(self._overflow) - self._parked_cancelled)
            return
        # the lock orders this enqueue against close()'s drain: either the
        # request lands before the drain (and is failed by it) or it sees
        # _stop and is rejected here — it can never be silently stranded
        with self._submit_lock:
            if self._stop.is_set():
                req.future.set_exception(RuntimeError("engine is closed"))
                return
            self._q.put(req)

    def _enqueue_admitted(self, req: _Request) -> None:  # holds: _submit_lock
        """Put an admitted request on the worker queue; its slot is held
        until the future resolves, then handed to the next parked request.
        Caller holds _submit_lock. If the future is already done (a cancel
        won a race), add_done_callback fires synchronously in this thread —
        safe because _submit_lock is reentrant."""
        req.future.add_done_callback(self._on_admitted_done)
        self._q.put(req)

    def _on_parked_done(self, fut) -> None:
        if fut.cancelled():
            with self._submit_lock:
                self._parked_cancelled += 1

    def _drop_parked(self, r) -> bool:  # holds: _submit_lock
        """Pop predicate: discard done (cancelled-while-parked) entries,
        reconciling the phantom counter as they physically leave the heap.
        Caller holds _submit_lock; pop discards a matched entry exactly
        once."""
        if r.future.done():
            if r.future.cancelled():
                self._parked_cancelled -= 1
            return True
        return False

    def _on_admitted_done(self, _fut) -> None:
        with self._submit_lock:
            if not self._stop.is_set():
                # requests cancelled while parked hold no slot: drop them
                nxt = self._overflow.pop(drop=self._drop_parked)
                if nxt is not None:
                    self._admission.admit_transfer(
                        time.perf_counter() - nxt.t_submit)
                    self._enqueue_admitted(nxt)
                    return
            self._admission.release()

    def warmup(self, batch_sizes=None, *, buckets=None, sampled: bool = False,
               timeout: float = 600) -> None:
        """Compile every batch shape a workload can hit, so jit compiles
        land here instead of inside the first measured request.

        Every bucket in ``buckets`` (default: all ``pad_buckets`` — a
        mixed-length workload pays a first-request compile per bucket it
        touches, not just ``pad_buckets[0]``) is primed for every batch
        size in ``batch_sizes`` (default ``1..max_batch``). Encoder and
        batch-at-a-time decoder modes serve one synthetic batch per
        (bucket, size) through the serve path; the continuous decoder
        primes each bucket's prefill-into-slot join sizes, its chunked-
        prefill shapes (when ``prefill_chunk`` is set) and its decode
        segment directly against the bucket's pool — with
        ``segment_width='adaptive'``, the segment is primed per (bucket x
        width tier), plus the compact-gather and scatter-back variants
        each occupancy in ``batch_sizes`` maps to, so tier switches
        mid-serve stay compile-clean — deterministic, unlike
        a burst of real requests whose join sizes depend on timing, and
        without adding request samples to ``metrics()``. It must run
        before serving traffic (it touches the pools the worker uses;
        raises once requests are in flight). ``metrics()['jit_compiles']``
        counts compiled serving variants (engine fns + the shared cache-
        pool helpers); ``window()`` diffs it, so a measured span can
        assert it stayed compile-clean. Encoder / batch-at-a-time warmup
        serves real synthetic batches, which count into the cumulative
        ``metrics()`` — callers measuring afterwards should attribute via
        ``window()``.

        ``sampled=True`` additionally primes the temperature>0 variant of
        every continuous-path shape (prefill, chunk, segments at every
        tier) — sampling keys a separate jit specialization (the top-k
        sort and PRNG enter the graph), so workloads measuring sampled
        traffic need it to stay compile-clean. Off by default: it roughly
        doubles warmup compile work and greedy-only callers never hit
        those variants.
        """
        buckets = tuple(buckets) if buckets else self.ec.pad_buckets
        sizes = sorted(set(batch_sizes or range(1, self.ec.max_batch + 1)))
        if self.continuous_active:
            self._warmup_continuous(buckets, sizes, sampled=sampled)
            return
        for bucket in buckets:
            tok = np.ones(bucket, np.int32)    # full width -> this bucket
            for b in sizes:
                self._serve_batch([
                    _Request(tok.copy(), Future(), time.perf_counter())
                    for _ in range(b)])

    def _warmup_continuous(self, buckets, sizes, sampled=False) -> None:
        """Prime the continuous scheduler's jitted shapes per bucket:
        prefill-into-slot per join size (gather acquire, as the scheduler
        uses), prefill chunks per fill-batch size, the full-slot decode
        segment (donating and swapping the pool caches exactly as a live
        segment does), and — under ``segment_width='adaptive'`` — one
        compact-gather -> tier-width segment -> scatter-back cycle per
        occupancy in ``sizes``, compiling exactly the variants those
        occupancies map to (gather and segment specialize per tier,
        scatter-back per (tier, occupancy)). With the prefix cache on,
        the store->slot load per hit-batch size and the store's
        truncating insert copy are primed too (suffix prefill reuses the
        chunk shapes). ``sampled=True`` repeats prefill/chunk/segments
        with temperature>0 arrays — the sampling jit variants.

        Beyond compiles, this also fronts the first-traffic allocation
        work the lazy paths used to pay mid-serve (the ~20x first-request
        warm-in, invisible to ``jit_compiles``): each bucket's chunked-
        prefill staging pool and prefix store are created (device
        allocations) here, and inputs are staged host-side first so the
        first measured request pays no first-transfer setup either."""
        if (self.latencies or not self._q.empty()
                or any(l.busy for l in self._scheduler.lanes.values())):
            # the worker would race these direct pool mutations (both
            # sides donate pool.caches); the old request-burst warmup was
            # traffic-safe, so fail loudly rather than corrupt quietly
            raise RuntimeError("warmup() must run before serving traffic")
        n = self.ec.max_batch
        chunk = self.ec.prefill_chunk

        def svariants(b):
            out = [(None, None, None)]
            if sampled:
                out.append((jnp.asarray(np.full(b, 0.5, np.float32)),
                            jnp.asarray(np.zeros(b, np.int32)),
                            jnp.asarray(np.zeros(b, np.int32))))
            return out

        for bucket in buckets:
            pool = self._get_pool(bucket)
            spec = self.ec.spec_decode
            dpool = self._get_draft_pool(bucket) if spec else None
            if spec:       # draft device pool allocs front-loaded too
                jax.block_until_ready(jax.tree.leaves(dpool.caches)[0])
            chunked = chunk is not None and bucket > chunk
            if chunked:
                # create the fill path's staging pool now — first-traffic
                # device allocs otherwise land inside the first request
                lane = self._scheduler.lanes[bucket]
                jax.block_until_ready(lane.get_staging(self).caches)
            store = self._prefix_store(bucket)
            for b in sizes:
                for sargs in svariants(b):
                    slots, view = pool.acquire(
                        [f"warm{bucket}.{i}" for i in range(b)], gather=True)
                    toks = jnp.asarray(np.zeros((b, bucket), np.int32))
                    lens = jnp.full((b,), min(4, bucket), jnp.int32)
                    tok, caches = self._prefill_fn()(
                        self.params, toks, lens, view, *sargs)
                    if spec:
                        # the live spec install path truncates the padded
                        # prefill tail in the same fused scatter (verify
                        # chunks attend the whole ring, so positions past
                        # a row's frontier must hold the empty sentinel)
                        pool.scatter_rollback(slots, caches,
                                              [min(4, bucket)] * b)
                    else:
                        pool.write_back(slots, caches)
                    jax.block_until_ready(tok)
                    pool.release_many(slots)
                    if chunked:
                        slots = pool.assign_many(
                            [f"warmc{bucket}.{i}" for i in range(b)])
                        # the fill path gathers fragmented staging slots via
                        # _take_slots; batch_view on this fresh pool would
                        # take the slice path and leave the gather uncompiled
                        view = _take_slots(pool.caches,
                                           jnp.asarray(slots, jnp.int32))
                        ctok, caches = self._chunk_fn()(
                            self.params,
                            jnp.asarray(np.zeros((b, chunk), np.int32)),
                            jnp.zeros((b,), jnp.int32),
                            jnp.full((b,), chunk, jnp.int32), view,
                            *sargs)
                        pool.write_back(slots, caches)
                        if spec:
                            # mid-fill chunks write_back to staging (primed
                            # above — same leaf shapes); the fill-complete
                            # install additionally rolls back, so prime
                            # that variant too
                            pool.scatter_rollback(
                                slots, pool.batch_view(slots, gather=True),
                                [chunk] * b)
                        jax.block_until_ready(ctok)
                        pool.release_many(slots)
                if spec:
                    # draft whole-prompt prefill + rollback per join size,
                    # driven with the module helpers at lane slot indices
                    # exactly as the scheduler does (no claim/release)
                    sl = jnp.asarray(list(range(b)), jnp.int32)
                    dpool.caches, dview = kvcache._reset_and_view(
                        dpool.caches, dpool._template, sl)
                    dcaches = self._draft_prefill_fn()(
                        self.draft_params,
                        jnp.asarray(np.zeros((b, bucket), np.int32)), dview)
                    dpool.caches = kvcache._scatter_rollback(
                        dpool.caches, dcaches, sl,
                        jnp.full((b,), min(4, bucket), jnp.int32))
                    jax.block_until_ready(jax.tree.leaves(dpool.caches)[0])
                if store is not None:
                    # hit path: claimed (unreset) slots + fused store->lane
                    # copy, per hit-batch size; the suffix chunk call and
                    # write_back reuse shapes primed above
                    slots = pool.claim(
                        [f"warmp{bucket}.{i}" for i in range(b)])
                    pool.caches = kvcache._load_slots(
                        pool.caches, store.pool.caches,
                        jnp.asarray(slots, jnp.int32),
                        jnp.asarray(np.zeros(b, np.int32)))
                    jax.block_until_ready(jax.tree.leaves(pool.caches)[0])
                    pool.release_many(slots)
            if store is not None:    # insert-on-complete's truncating copy
                store.pool.caches = kvcache._store_prefix(
                    store.pool.caches, pool.caches,
                    jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32),
                    jnp.asarray(chunk, jnp.int32))
                jax.block_until_ready(
                    jax.tree.leaves(store.pool.caches)[0])
            if spec:
                # spec lanes never run decode segments — every turn is a
                # compacted draft-and-verify round (even 'fixed' runs the
                # gather path at width max_batch), so prime the round per
                # width tier plus the (occupancy, width) rollback variants
                for occ in sizes:
                    width = pick_tier(occ, self._tiers)
                    for sargs_w in svariants(width):
                        slots = list(range(occ))
                        _, view = pool.compact_view(slots, width)
                        _, dview = dpool.compact_view(slots, width)
                        _, verify, seg, dseg = self._spec_round_fn()(
                            self.params, self.draft_params,
                            jnp.zeros((width, 1), jnp.int32),
                            jnp.zeros((width, 1), jnp.int32),
                            view, dview, *sargs_w)
                        pool.scatter_rollback(slots, seg, [1] * occ)
                        dpool.scatter_rollback(slots, dseg, [1] * occ)
                        jax.block_until_ready(verify)
                continue
            for sargs_n in svariants(n):
                toks, _, _, caches = self._segment_fn()(
                    self.params, jnp.zeros((n, 1), jnp.int32),
                    jnp.zeros((n, 1), jnp.int32), pool.caches,
                    jnp.zeros((n,), bool), jnp.ones((n,), jnp.int32),
                    jnp.full((n,), -1, jnp.int32), *sargs_n)
                pool.caches = caches
                jax.block_until_ready(toks)
            for occ in sizes:        # compacted segments per width tier
                width = pick_tier(occ, self._tiers)
                if width >= n:       # occupancy maps to the full segment
                    continue
                for sargs_w in svariants(width):
                    slots = list(range(occ))
                    _, view = pool.compact_view(slots, width)
                    toks, _, _, seg = self._segment_fn()(
                        self.params, jnp.zeros((width, 1), jnp.int32),
                        jnp.zeros((width, 1), jnp.int32), view,
                        jnp.zeros((width,), bool),
                        jnp.ones((width,), jnp.int32),
                        jnp.full((width,), -1, jnp.int32), *sargs_w)
                    pool.scatter_back(slots, seg)
                    jax.block_until_ready(toks)

    def discard_samples(self) -> None:
        """Drop the accumulated per-request samples (wall latencies, batch
        sizes, phase timings) and re-sync the ``window()`` cursor — the
        one way to discard warmup traffic so later ``metrics()`` /
        ``window()`` spans cover only measured requests. Counters
        (segments, joins, compiles, lane stats) are cumulative by design
        and are not touched; attribute those via ``window()``."""
        self.latencies.clear()
        self.batch_sizes.clear()
        self.timings.clear()
        self.window()

    def close(self):
        self._stop.set()
        self._worker.join(timeout=5)
        # fail everything still parked or queued: resolves client futures
        # (and, via the done-callbacks, frees any held admission slots)
        with self._submit_lock:
            pending = self._overflow.drain()
        while True:
            try:
                pending.append(self._q.get_nowait())
            except queue.Empty:
                break
        for req in pending:
            if not req.future.done():
                req.future.set_exception(RuntimeError("engine is closed"))

    # ------------------------------------------------------------- server
    def _bucket(self, n: int) -> int:
        for b in self.ec.pad_buckets:
            if n <= b:
                return b
        raise RequestTooLong(
            f"request of {n} tokens exceeds the largest pad bucket "
            f"({self.ec.pad_buckets[-1]}); split the request or configure "
            f"larger pad_buckets")

    def _encoder_fn(self, bucket: int):  # holds: worker
        if ("enc", bucket) not in self._compiled:
            def fn(params, tokens, mask):
                pos = jnp.broadcast_to(
                    jnp.arange(tokens.shape[1], dtype=jnp.int32),
                    tokens.shape)
                # GECToR-style param trees nest the encoder under 'encoder'
                enc_params = params.get("encoder", params)
                hid, _, _ = forward(self.cfg, enc_params, tokens=tokens,
                                    positions=pos, causal=False,
                                    return_hidden=True)
                if self.head_fn is not None:
                    return self.head_fn(params, hid, mask)
                return hid
            self._compiled[("enc", bucket)] = jax.jit(fn)
        return self._compiled[("enc", bucket)]

    # --------------------------------------------------- decoder hot path
    def _sampling_arrays(self, reqs: List[_Request]):  # holds: worker
        """Per-row sampling/stop arrays from a request batch; legacy
        requests (no SamplingParams) default to greedy full-budget rows."""
        T = self.ec.max_new_tokens
        B = len(reqs)
        temp = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        seed = np.zeros(B, np.int32)
        eos = np.full(B, -1, np.int32)
        budget = np.full(B, T, np.int32)
        any_sample = False
        for i, r in enumerate(reqs):
            sp = r.sampling
            if sp is None:
                continue
            budget[i] = r.budget
            if sp.eos_id is not None:
                eos[i] = sp.eos_id
            if sp.temperature > 0:
                any_sample = True
                temp[i] = sp.temperature
                topk[i] = sp.top_k or 0
                seed[i] = sp.seed
        return temp, topk, seed, eos, budget, any_sample

    def _decode_scan_fn(self):  # holds: worker
        """One fused jitted function: prefill -> per-row last-position
        first-token selection -> ``decode_segment`` over the remaining
        steps. jit specializes it per (batch, bucket) shape — and per
        sampling-on/off (greedy batches pass None and keep the sort/PRNG
        out of the graph); one dispatch serves the whole batch."""
        if "dec_scan" not in self._compiled:
            T = self.ec.max_new_tokens

            def fn(params, toks, lens, caches, temp, topk, seed):
                logits, caches, _ = forward(self.cfg, params, tokens=toks,
                                            caches=caches, mode="full")
                # first generated token: per-row logits at the row's real
                # last position (padded rows must not sample from garbage)
                last = jnp.take_along_axis(
                    logits, (lens - 1)[:, None, None], axis=1)[:, 0]
                tok = sample_logits(last, temperature=temp, top_k=topk,
                                    seed=seed, positions=lens)[:, None]
                if T == 1:
                    return tok, caches
                rest, _, _, caches = decode_segment(
                    self.cfg, params, tok, lens[:, None], caches,
                    n_steps=T - 1, temperature=temp, top_k=topk, seed=seed)
                return jnp.concatenate([tok, rest], axis=1), caches

            self._compiled["dec_scan"] = jax.jit(fn)
        return self._compiled["dec_scan"]

    def _decode_fns(self):  # holds: worker
        """Legacy per-token path (kept for A/B benchmarks + equivalence
        tests; ``use_scan_decode=False`` selects it; greedy only).
        unroll_periods=False reproduces the seed's scanned-period step
        structure exactly."""
        if "dec" not in self._compiled:
            self._compiled["dec"] = (
                jax.jit(lambda p, t, c: forward(self.cfg, p, tokens=t,
                                                caches=c, mode="full")),
                jax.jit(lambda p, t, pos, c: decode_step(
                    self.cfg, p, t, pos, c, unroll_periods=False)),
            )
        return self._compiled["dec"]

    def _prefill_fn(self):  # holds: worker
        """Continuous-batching prefill-into-slot: fill the rows' pool-slot
        caches and select each row's first token. jit specializes per
        (n_new, bucket) shape."""
        if "cont_prefill" not in self._compiled:
            def fn(params, toks, lens, caches, temp, topk, seed):
                logits, caches, _ = forward(self.cfg, params, tokens=toks,
                                            caches=caches, mode="full")
                last = jnp.take_along_axis(
                    logits, (lens - 1)[:, None, None], axis=1)[:, 0]
                tok = sample_logits(last, temperature=temp, top_k=topk,
                                    seed=seed, positions=lens)
                return tok, caches
            self._compiled["cont_prefill"] = jax.jit(fn)
        return self._compiled["cont_prefill"]

    def _chunk_fn(self):  # holds: worker
        """Chunked-prefill step: run one prompt chunk against the rows'
        staged caches (``models.prefill_chunk``) and select each row's
        next-token candidate at its last valid chunk position — only
        meaningful for rows whose prompt completes this chunk; the
        scheduler ignores it for the rest. ``start`` is each row's
        absolute chunk offset, ``nvalid`` its real tokens this chunk (all
        chunks except a prompt's last are completely filled). jit
        specializes per (n_fills, chunk_len) shape."""
        if "cont_chunk" not in self._compiled:
            def fn(params, toks, start, nvalid, caches, temp, topk, seed):
                C = toks.shape[1]
                positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)
                logits, caches, _ = prefill_chunk(
                    self.cfg, params, toks, positions, caches)
                last = jnp.take_along_axis(
                    logits, (nvalid - 1)[:, None, None], axis=1)[:, 0]
                # PRNG counter = the position the sampled token occupies
                # (the prompt length) — identical to whole-prompt prefill
                tok = sample_logits(last, temperature=temp, top_k=topk,
                                    seed=seed, positions=start + nvalid)
                return tok, caches
            self._compiled["cont_chunk"] = jax.jit(fn)
        return self._compiled["cont_chunk"]

    def _segment_fn(self):  # holds: worker
        """One jitted decode segment over the full slot batch (the
        continuous scheduler's step core). The pool caches are donated:
        the segment updates them in place and the scheduler swaps in the
        returned tree."""
        if "cont_segment" not in self._compiled:
            seg = self.ec.decode_segment

            def fn(params, tok, pos, caches, active, budget, eos,
                   temp, topk, seed):
                return decode_segment(self.cfg, params, tok, pos, caches,
                                      n_steps=seg, active=active,
                                      budget=budget, eos_id=eos,
                                      temperature=temp, top_k=topk,
                                      seed=seed)

            self._compiled["cont_segment"] = jax.jit(fn, donate_argnums=3)
        return self._compiled["cont_segment"]

    def _draft_prefill_fn(self):  # holds: worker
        """Whole-prompt prefill into the draft pool's slot caches. No
        token selection — the round's first draft step samples from the
        prompt's last position — and ``return_hidden`` keeps the draft
        lm_head out of the graph. jit specializes per (n_new, bucket)."""
        if "spec_dprefill" not in self._compiled:
            def fn(dparams, toks, caches):
                _, caches, _ = forward(self.draft_cfg, dparams, tokens=toks,
                                       caches=caches, mode="full",
                                       return_hidden=True)
                return caches
            self._compiled["spec_dprefill"] = jax.jit(fn)
        return self._compiled["spec_dprefill"]

    def _spec_round_fn(self):  # holds: worker
        """One fused draft-and-verify round (``models.spec_round``): spec_k
        draft decode steps + one target verify chunk, one dispatch. Both
        cache views are donated — the scheduler scatter-rollbacks the
        returned trees to each row's commit boundary."""
        if "spec_round" not in self._compiled:
            k = self.ec.spec_k

            def fn(params, dparams, tok, pos, caches, dcaches,
                   temp, topk, seed):
                return spec_round(self.cfg, params, self.draft_cfg, dparams,
                                  tok, pos, caches, dcaches, k=k,
                                  temperature=temp, top_k=topk, seed=seed)

            self._compiled["spec_round"] = jax.jit(fn,
                                                   donate_argnums=(4, 5))
        return self._compiled["spec_round"]

    def _slot_len(self, bucket: int) -> int:  # holds: worker
        """KV ring length for the bucket's slots. Spec-decode rounds write
        a verify chunk of spec_k + 1 positions starting at the row's
        frontier, so a row one token short of its budget still reaches
        position bucket + max_new_tokens - 1 + spec_k — without the
        headroom the chunk would wrap the ring and overwrite the prompt's
        KV (the over-provisioned tail is rolled back, never committed)."""
        return (bucket + self.ec.max_new_tokens
                + (self.ec.spec_k if self.ec.spec_decode else 0))

    def _get_pool(self, bucket: int) -> CachePool:  # holds: worker
        pool = self._pools.get(bucket)
        if pool is None:
            pool = CachePool(self.cfg, self.ec.max_batch,
                             self._slot_len(bucket),
                             dtype=jnp.float32,
                             kv_quant=self.ec.kv_quant)
            self._pools[bucket] = pool
            if self.continuous_active:
                self._lane_stat(bucket)["kv_bytes"] = int(
                    sum(x.nbytes for x in jax.tree.leaves(pool.caches)))
        return pool

    def _get_draft_pool(self, bucket: int) -> CachePool:  # holds: worker
        """The bucket's draft-model KV pool. Slot i mirrors lane slot i
        (same indices, same ring length), but the pool bypasses slot
        bookkeeping entirely — the scheduler drives it with the module
        helpers at the lane's slot indices, so claim/release state lives
        only on the lane pool. Draft KV stays float even under kv_quant:
        its logits only gate proposals (never committed tokens), and the
        small draft's cache is not the residency bottleneck."""
        pool = self._draft_pools.get(bucket)
        if pool is None:
            pool = CachePool(self.draft_cfg, self.ec.max_batch,
                             self._slot_len(bucket), dtype=jnp.float32)
            self._draft_pools[bucket] = pool
        return pool

    def _prefix_store(self, bucket: int):  # holds: worker
        """The bucket's prefix store, or None when the prefix cache is off
        or the bucket cannot hold a full chunk-aligned prefix (a stored
        prefix is strictly shorter than the prompt, so buckets <= chunk
        can never match). Store slots share the lane pool's max_len, so
        loads are shape-identical full-slot copies."""
        if not self.ec.prefix_cache:
            return None
        C = self.ec.prefill_chunk
        if bucket <= C:
            return None
        store = self._prefix_stores.get(bucket)
        if store is None:
            store = kvcache.PrefixStore(
                self.cfg, self.ec.max_batch,
                self._slot_len(bucket), C,
                capacity_bytes=self.ec.prefix_cache_bytes,
                dtype=jnp.float32, kv_quant=self.ec.kv_quant)
            self._prefix_stores[bucket] = store
        return store

    def _acquire_caches(self, B: int, bucket: int):  # holds: worker
        """Batch-sized decode caches: pooled slots (reset-on-assign, no
        per-batch allocation sweep) or a fresh make_caches tree."""
        if not self.ec.use_cache_pool:
            L = bucket + self.ec.max_new_tokens
            return make_caches(self.cfg, B, L, dtype=jnp.float32,
                               kv_quant=self.ec.kv_quant), None
        pool = self._get_pool(bucket)
        slots, view = pool.acquire([f"b{bucket}.{i}" for i in range(B)])
        return view, (pool, slots)

    @staticmethod
    def _release_caches(handle):
        if handle is not None:
            pool, slots = handle
            pool.release_many(slots)

    def _serve_decoder(self, toks, lens, bucket, reqs):  # holds: worker
        """Batch-at-a-time decode. Returns (gen (B, T), emits (B, T) bool,
        eos_hit (B,) bool) — emits marks each row's kept prefix (its budget
        / first-eos trim)."""
        B = len(lens)
        temp, topk, seed, eos, budget, any_sample = \
            self._sampling_arrays(reqs)
        lens_a = jnp.asarray(np.array(lens, np.int32))
        caches, handle = self._acquire_caches(B, bucket)
        try:
            if self.ec.use_scan_decode:
                sargs = ((jnp.asarray(temp), jnp.asarray(topk),
                          jnp.asarray(seed)) if any_sample
                         else (None, None, None))
                gen, _ = self._decode_scan_fn()(
                    self.params, jnp.asarray(toks), lens_a, caches, *sargs)
                gen = np.asarray(gen)
            else:
                if any_sample:
                    raise ValueError("sampling requires use_scan_decode")
                prefill_fn, step_fn = self._decode_fns()
                logits, caches, _ = prefill_fn(self.params,
                                               jnp.asarray(toks), caches)
                last = jnp.take_along_axis(
                    logits, (lens_a - 1)[:, None, None], axis=1)
                tok = jnp.argmax(last[:, 0], axis=-1)[:, None]
                tok = tok.astype(jnp.int32)
                outs = [np.asarray(tok)]
                pos = lens_a[:, None] - 1
                for _ in range(self.ec.max_new_tokens - 1):
                    pos = pos + 1
                    logits, caches, _ = step_fn(self.params, tok, pos, caches)
                    tok = jnp.argmax(logits[:, -1:], axis=-1)
                    tok = tok.astype(jnp.int32)
                    outs.append(np.asarray(tok))
                gen = np.concatenate(outs, axis=1)
            emits, eos_hit = _trim_host(gen, eos, budget)
            return gen, emits, eos_hit
        finally:
            self._release_caches(handle)

    def _serve_batch(self, reqs: List[_Request]):  # holds: worker
        # claim each future (concurrent.futures protocol): a client-side
        # cancel() that won between enqueue and here drops the request
        # instead of poisoning set_result for the whole batch
        reqs = [r for r in reqs if r.future.set_running_or_notify_cancel()]
        if not reqs:
            return
        lens = [len(r.tokens) for r in reqs]
        bucket = self._bucket(max(lens))
        B = len(reqs)
        toks = np.zeros((B, bucket), np.int32)
        mask = np.zeros((B, bucket), bool)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
            mask[i, :len(r.tokens)] = True

        if self.ec.mode == "encoder":
            out = self._encoder_fn(bucket)(self.params, jnp.asarray(toks),
                                           jnp.asarray(mask))
            out = jax.device_get(out)
            # record samples BEFORE resolving futures: a client whose
            # .result() returns must find its sample in metrics()/window()
            self._record_batch(reqs)
            for i, r in enumerate(reqs):
                r.future.set_result(jax.tree.map(lambda x: x[i], out))
        else:
            t_serve = time.perf_counter()
            gen, emits, eos_hit = self._serve_decoder(toks, lens, bucket,
                                                      reqs)
            t_done = time.perf_counter()
            timings = []
            for r in reqs:
                timing = RequestTiming(queue_s=t_serve - r.t_submit,
                                       prefill_s=0.0,
                                       decode_s=t_done - t_serve)
                timings.append(timing)
                if r.handle is not None:
                    self.timings.append(timing)
            self._record_batch(reqs)
            for i, r in enumerate(reqs):
                if r.handle is None:    # legacy raw-batch caller
                    r.future.set_result(gen[i])
                    continue
                row = np.asarray(gen[i][emits[i]], np.int32)
                if r.handle.cancel_requested:  # cancel landed mid-serve
                    reason = FINISH_CANCELLED
                else:
                    reason = FINISH_EOS if eos_hit[i] else FINISH_LENGTH
                r.handle._push(row)
                r.future.set_result(GenerationResult(
                    tokens=row, finish_reason=reason, timing=timings[i],
                    request_id=r.handle.request.request_id))

    def _record_batch(self, reqs: List[_Request]) -> None:  # holds: worker
        now = time.perf_counter()
        self.batch_sizes.append(len(reqs))
        for r in reqs:
            self.latencies.append(now - r.t_submit)

    def _run(self):  # holds: worker
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.perf_counter() + self.ec.batch_window_ms / 1e3
            while len(batch) < self.ec.max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            try:
                self._serve_batch(batch)
            except Exception as e:  # pragma: no cover - surfaced to client
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)

    # ------------------------------------------------------------ metrics
    def _lane_stat(self, bucket: int) -> dict:  # holds: worker
        """Per-lane counters (scheduler-side accumulation point)."""
        stat = self.lane_stats.get(bucket)
        if stat is None:
            stat = self.lane_stats[bucket] = {
                "decode_segments": 0, "occupancy_sum": 0, "joins": 0,
                "prefill_chunks": 0, "compact_segments": 0,
                "prefix_hits": 0, "prefix_misses": 0,
                "prefix_hit_tokens": 0, "prefix_inserts": 0,
                "prefix_evictions": 0,
                "spec_rounds": 0,        # draft-and-verify rounds run
                "spec_proposed": 0,      # draft tokens offered (occ * k)
                "spec_accepted": 0,      # draft tokens the target agreed on

                "prefix_bytes": 0,   # gauges (see _LANE_GAUGES), not counters
                "kv_bytes": 0,       # lane pool KV residency (scales incl.)
                # segment width -> segments run at it. Every tier is
                # pre-created (like the outer key set) so the worker only
                # mutates values — metrics() iterates these dicts from
                # client threads without a lock; a lazily inserted key
                # would fault that iteration. Zero counts are dropped
                # from the reported view.
                "tier_hist": {w: 0 for w in self._tiers}}
        return stat

    def _jit_compiles(self) -> int:
        """Compiled specializations across the serving path's jitted
        functions — a counter measured spans can diff (via ``window()``)
        to assert a workload hit only warmed shapes. Includes the
        module-level cache-pool helpers (reset/gather/scatter): they are
        shared process-wide, but the window diff only surfaces compiles
        that happened during the span, which is the quantity a
        single-engine measurement cares about."""
        n = 0
        # snapshot: the worker inserts newly built fns concurrently
        pool_fns = (kvcache._reset_slots, kvcache._reset_and_view,
                    kvcache._reset_and_view_run, kvcache._take_slots,
                    kvcache._write_slots, kvcache._scatter_prefix,
                    kvcache._load_slots, kvcache._store_prefix,
                    kvcache._scatter_rollback)
        for fn in list(self._compiled.values()) + list(pool_fns):
            fns = fn if isinstance(fn, tuple) else (fn,)
            for f in fns:
                size = getattr(f, "_cache_size", None)
                if callable(size):
                    n += size()
        return n

    # lane stats reported as current values, not window-diffed deltas
    _LANE_GAUGES = frozenset({"prefix_bytes", "kv_bytes"})

    @classmethod
    def _lane_view(cls, now: dict, prev: Optional[dict] = None) -> dict:
        """Lane counter dicts (optionally diffed against a window cursor)
        with the occupancy mean derived per span. Dict-valued counters
        (the segment-width ``tier_hist``) diff per key, dropping keys that
        did not move — a window's histogram covers only its span. Gauges
        (``prefix_bytes``) pass through undiffed: a window reports the
        store's current residency, not its movement."""
        out = {}
        for bucket, stat in now.items():
            base = (prev or {}).get(bucket, {})
            d = {}
            for k, v in stat.items():
                if isinstance(v, dict):
                    sub = base.get(k, {})
                    d[k] = {w: c - sub.get(w, 0) for w, c in v.items()
                            if c - sub.get(w, 0)}
                elif k in cls._LANE_GAUGES:
                    d[k] = v
                else:
                    d[k] = v - base.get(k, 0)
            segs = d.get("decode_segments", 0)
            d["occupancy_mean"] = (d.pop("occupancy_sum", 0) / segs
                                   if segs else 0.0)
            prop = d.get("spec_proposed", 0)
            d["spec_accept_rate"] = (d.get("spec_accepted", 0) / prop
                                     if prop else 0.0)
            out[bucket] = d
        return out

    def _aggregate(self, latencies, batch_sizes, timings, stats) -> dict:
        """Reduce one span of serving samples to the metrics dict shape."""
        n = len(latencies)
        m = {"requests": n}
        if n:
            lat = np.array(latencies)
            m.update(latency_mean_s=float(lat.mean()),
                     latency_p50_s=float(np.percentile(lat, 50)),
                     latency_p95_s=float(np.percentile(lat, 95)))
        else:
            m.update(latency_mean_s=None, latency_p50_s=None,
                     latency_p95_s=None)
        m["batch_size_mean"] = (float(np.mean(batch_sizes))
                                if batch_sizes else 0.0)
        if timings:
            m["queue_wait_mean_s"] = float(
                np.mean([t.queue_s for t in timings]))
            m["prefill_mean_s"] = float(
                np.mean([t.prefill_s for t in timings]))
            m["decode_mean_s"] = float(
                np.mean([t.decode_s for t in timings]))
        if self.continuous_active:
            # batch_sizes holds per-segment occupancy in continuous mode
            m["batch_occupancy_mean"] = m["batch_size_mean"]
            m.update(stats)
        return m

    def metrics(self) -> dict:
        """Cumulative serving stats since engine start. With no completed
        requests the latency percentiles are None (never fabricated from a
        zero sample). Continuous engines additionally report per-lane
        counters under ``'lanes'`` (bucket -> segments / occupancy mean /
        joins / prefill chunks / compacted-segment count / ``tier_hist``,
        the histogram of decode-segment widths the lane actually ran) and
        ``'jit_compiles'`` (compiled engine specializations so far).
        ``window()`` gives the same shape for the span since the previous
        ``window()`` call."""
        m = self._aggregate(self.latencies, self.batch_sizes, self.timings,
                            self._stats)
        m["weight_bytes"] = self._weight_bytes
        if self.continuous_active:
            m["lanes"] = self._lane_view(self.lane_stats)
            m["jit_compiles"] = self._jit_compiles()
        if self._admission is not None:
            adm = self._admission.snapshot()   # consistent read under _lock
            m["admission_peak_queue"] = adm.queued_peak
            m["admission_wait_total_s"] = adm.wait_total_s
        return m

    def window(self) -> dict:
        """Snapshot-style metrics: everything since the previous
        ``window()`` call (or engine start), then reset the window. Lets
        the experiment runner attribute occupancy/join/segment counters to
        one experiment window instead of cumulative totals. The worker
        appends samples, so a concurrent append mid-call only shifts a
        sample into the next window — never loses it. A cursor beyond the
        current length means the caller cleared the sample lists
        (``run_ladder(warmup=True)`` does): that window restarts at the
        clear instead of silently skipping post-clear samples."""
        cur = self._win_cursor
        i_lat, i_bs, i_tim = (len(self.latencies), len(self.batch_sizes),
                              len(self.timings))
        stats_now = dict(self._stats)
        # per-key copy: tier_hist is a nested dict the scheduler mutates
        lanes_now = {b: {k: (dict(v) if isinstance(v, dict) else v)
                         for k, v in s.items()}
                     for b, s in self.lane_stats.items()}

        def span(lst, start, stop):
            return lst[start if start <= stop else 0:stop]

        m = self._aggregate(span(self.latencies, cur["latencies"], i_lat),
                            span(self.batch_sizes, cur["batch_sizes"], i_bs),
                            span(self.timings, cur["timings"], i_tim),
                            {k: v - cur["stats"].get(k, 0)
                             for k, v in stats_now.items()})
        m["weight_bytes"] = self._weight_bytes     # gauge, not diffed
        if self.continuous_active:
            m["lanes"] = self._lane_view(lanes_now, cur.get("lanes"))
            compiles = self._jit_compiles()
            m["jit_compiles"] = compiles - cur.get("jit_compiles", 0)
            self._win_cursor = {"latencies": i_lat, "batch_sizes": i_bs,
                                "timings": i_tim, "stats": stats_now,
                                "lanes": lanes_now,
                                "jit_compiles": compiles}
        else:
            self._win_cursor = {"latencies": i_lat, "batch_sizes": i_bs,
                                "timings": i_tim, "stats": stats_now,
                                "lanes": lanes_now}
        return m
