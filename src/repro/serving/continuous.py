"""Multi-lane step-driven continuous-batching scheduler.

The single-set predecessor closed the ROADMAP's "continuous batching at
step granularity" item but kept two head-of-line cliffs it opened:

  * **cross-bucket blocking** — all in-flight rows shared one pad bucket,
    so a request padding to a different bucket waited for the entire set
    to drain (and ``_admit`` re-scanned the whole pending heap every
    segment while those foreign-bucket requests sat in it);
  * **prefill stalls** — a join's prefill ran its whole prompt in one
    call between segments, stalling every in-flight row for the full
    prompt's forward.

This scheduler fixes both. Each pad bucket gets its own **lane** — a
``CachePool``-backed slot batch with per-slot decode state, occupancy
counters, and a per-lane pending queue (``scheduler.LaneQueue``: O(log n)
lane-aware pop, no cross-bucket rescans) — and the worker round-robins
jitted decode segments (``models.decode_segment``) across non-empty lanes,
so a bucket-64 request admits into free bucket-64 slots immediately while
the bucket-32 set keeps decoding. Between a lane's segments (a host sync
it needs anyway to stream tokens) the worker:

  * retires rows that finished in-graph (per-row eos / budget stop),
    releasing their slot and resolving their future;
  * retires rows whose client cancelled mid-decode (or mid-prefill);
  * admits the best pending requests per lane (priority order, FIFO
    within a level) via prefill-into-slot;
  * advances **chunked prefills**: a join whose prompt exceeds
    ``EngineConfig.prefill_chunk`` prefills ``models.prefill_chunk``-sized
    chunks into a staging pool slot — one chunk per scheduler turn,
    interleaved with decode segments — and is copied into its reserved
    lane slot (one chunk-granular ``CachePool.write_back``) when the
    prompt completes, so a 512-token join no longer stalls every in-flight
    row for the whole prompt's prefill. The staging slot (not the live
    lane slot) absorbs the chunks because inactive rows idempotently
    re-write their frozen KV every segment — a partially filled live slot
    would be corrupted between chunks.

``EngineConfig.multi_lane=False`` keeps the legacy single-set admission
gate (one bucket serves until it drains) for A/B runs — the
``bench_multi_bucket`` baseline.

Decode segments are **occupancy-adaptive** (the fixed-width follow-on the
ROADMAP tracked): before each segment the scheduler picks the smallest
width tier (powers of two up to ``max_batch`` — ``scheduler.width_tiers``)
that fits the lane's live rows, compacts those rows' KV slots and decode
state into a tier-width view (``CachePool.compact_view`` — one fused
gather), runs ``models.decode_segment`` at that width, and scatters the
results back to the home slots (``CachePool.scatter_back`` — padding rows
are dropped, so untouched slots stay bitwise identical). A lane whose one
long request decodes alone thus pays a width-1-or-2 segment, not
``max_batch``. ``EngineConfig.segment_width='fixed'`` keeps the
always-full-width segment as the A/B baseline (``bench_segment_width``);
either way correctness never depends on occupancy, and each tier is one
compiled function per bucket (primed by ``engine.warmup()``).
Per-segment occupancy lands in ``engine.batch_sizes`` and per-lane
segment/occupancy/join/chunk/compaction counters plus the segment-width
``tier_hist`` in ``engine.metrics()['lanes']``.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.api import (FINISH_CANCELLED, FINISH_EOS, FINISH_LENGTH,
                               GenerationResult, RequestTiming)
from repro.serving import kvcache
from repro.serving.kvcache import CachePool
from repro.serving.scheduler import LaneQueue, pick_tier


@dataclasses.dataclass(eq=False)     # identity semantics: list.remove /
class _Row:                          # membership must not compare the
    req: "object"                    # engine._Request (np token arrays)
    slot: int
    toks: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(eq=False)
class _Fill:
    """A join whose prompt is prefilling chunk-by-chunk: ``slot`` is its
    reserved lane slot (written once, when the prompt completes), ``stg``
    its staging-pool slot (written every chunk), ``filled`` the prompt
    tokens staged so far. Sampling/stop arrays are frozen at claim time so
    chunk batches regroup freely across scheduler turns."""
    req: "object"
    slot: int
    stg: int
    filled: int = 0
    matched: int = 0       # prompt tokens reused from the prefix store
    temp: float = 0.0
    topk: int = 0
    seed: int = 0
    eos: int = -1
    budget: int = 0


class _Lane:
    """One pad bucket's in-flight set: pool slots + per-slot decode state.

    State arrays are indexed by pool slot; free and prefilling slots ride
    along inactive (``active=False``) in every segment, idempotently
    re-writing their frozen KV position — correctness never depends on
    occupancy, and reset-on-assign wipes a slot when it is re-acquired.
    """

    def __init__(self, eng, bucket: int):
        self._eng = eng                  # guarded-by: init
        self.bucket = bucket             # guarded-by: init
        self.staging: Optional[CachePool] = None   # guarded-by: worker
        n = eng.ec.max_batch
        self.last_tok = np.zeros(n, np.int32)   # guarded-by: worker — last sampled
        self.pos = np.zeros(n, np.int32)        # guarded-by: worker — abs position
        self.active = np.zeros(n, bool)         # guarded-by: worker
        self.budget = np.zeros(n, np.int32)     # guarded-by: worker — tokens left
        self.eos = np.full(n, -1, np.int32)     # guarded-by: worker
        self.temp = np.zeros(n, np.float32)     # guarded-by: worker
        self.topk = np.zeros(n, np.int32)       # guarded-by: worker
        self.seed = np.zeros(n, np.int32)       # guarded-by: worker
        self.rows: Dict[int, _Row] = {}         # guarded-by: worker — slot -> _Row
        self.fills: List[_Fill] = []            # guarded-by: worker — chunked prefills

    @property
    def busy(self) -> bool:  # holds: worker
        return bool(self.rows or self.fills)

    @property
    def pool(self) -> CachePool:
        """The bucket's slot pool — resolved through the engine's pool
        cache so pre-creating every lane at scheduler construction (the
        worker iterates ``lanes.values()``; lazy insertion from client
        threads raced that) does not eagerly allocate device caches for
        buckets the workload never touches."""
        return self._eng._get_pool(self.bucket)

    def get_staging(self, eng) -> CachePool:  # holds: worker
        if self.staging is None:
            # engine._slot_len keeps staging slots shape-identical to lane
            # slots (full-slot copies at fill-complete install), including
            # spec-decode's verify-chunk ring headroom
            self.staging = CachePool(
                eng.cfg, eng.ec.max_batch,
                eng._slot_len(self.bucket), dtype=jnp.float32,
                kv_quant=eng.ec.kv_quant)
        return self.staging


class ContinuousScheduler:
    def __init__(self, engine):
        self.eng = engine                # guarded-by: init
        # every lane exists up front (device pools stay lazy — see
        # _Lane.pool): the worker's idle/busy checks iterate this dict,
        # and lazily inserting lanes from warmup or client threads raced
        # that iteration — part of the first-traffic warm-in
        self.lanes: Dict[int, _Lane] = {  # guarded-by: worker
            b: _Lane(engine, b) for b in engine.ec.pad_buckets}
        self.pending = LaneQueue()              # guarded-by: worker — pending queues
        self._rr = 0                            # guarded-by: worker — round-robin

    def _lane(self, bucket: int) -> _Lane:  # holds: worker
        return self.lanes[bucket]

    # ------------------------------------------------------------ worker
    def run(self):  # holds: worker
        eng = self.eng
        try:
            while not eng._stop.is_set():
                try:
                    idle = not self.pending and not any(
                        l.busy for l in self.lanes.values())
                    self._drain(block=idle)
                    self._admit()
                    lane = self._next_lane()
                    if lane is not None:
                        self._step(lane)
                except Exception as e:  # surfaced to the affected clients
                    self._fail_inflight(e)
        finally:
            self._shutdown()

    def _drain(self, block: bool) -> None:  # holds: worker
        """Move newly submitted requests into their lane's pending queue;
        when idle, block briefly so the loop doesn't spin."""
        eng = self.eng
        try:
            while True:
                req = (eng._q.get(timeout=0.05) if block
                       else eng._q.get_nowait())
                block = False
                self.pending.push(req, req.priority,
                                  lane=eng._bucket(len(req.tokens)))
        except queue.Empty:
            pass

    def _next_lane(self) -> Optional[_Lane]:  # holds: worker
        """Round-robin over lanes with in-flight work, so no bucket's
        decode starves while another bucket is busy."""
        busy = [l for l in self.lanes.values() if l.busy]
        if not busy:
            return None
        self._rr = (self._rr + 1) % len(busy)
        return busy[self._rr]

    def _step(self, lane: _Lane) -> None:  # holds: worker
        """One scheduler turn for a lane: advance its chunked prefills by
        one chunk, then run one decode segment for its in-flight rows —
        the interleave that bounds how long a join can stall decode."""
        if lane.fills:
            self._fill_chunk(lane)
        if lane.rows:
            if self.eng.ec.spec_decode:
                self._spec_round(lane)
            else:
                self._segment(lane)

    # --------------------------------------------------------- admission
    def _admit(self) -> None:  # holds: worker
        eng = self.eng
        if not self.pending:
            return
        drop = lambda r: r.future.done()    # noqa: E731 — cancelled in queue
        if eng.ec.multi_lane:
            buckets = self.pending.lanes()
        else:
            # legacy single-set gate (A/B baseline): one bucket serves
            # until it fully drains; the next is picked by the globally
            # best pending request — the head-of-line cliff lanes remove
            busy = [b for b, l in self.lanes.items() if l.busy]
            if busy:
                buckets = [b for b in busy if self.pending.lane_len(b)]
            else:
                best = self.pending.best_lane(drop)
                buckets = [] if best is None else [best]
        any_busy = any(l.busy for l in self.lanes.values())
        for bucket in buckets:
            lane = self._lane(bucket)
            claimed = []
            while lane.pool.free_slots > len(claimed):
                r = self.pending.pop(bucket, drop=drop)
                if r is None:
                    break
                claimed.append(r)
            claimed = [r for r in claimed
                       if r.future.set_running_or_notify_cancel()]
            if not claimed:
                continue
            if any_busy:
                eng._stats["joins_mid_flight"] += len(claimed)
                eng._lane_stat(bucket)["joins"] += len(claimed)
            any_busy = True
            chunk = eng.ec.prefill_chunk
            store = eng._prefix_store(bucket)
            whole, hits, fills, fill_entries = [], [], [], []
            for r in claimed:
                entry = store.lookup(r.tokens) if store is not None else None
                if entry is not None:
                    stat = eng._lane_stat(bucket)
                    stat["prefix_hits"] += 1
                    stat["prefix_hit_tokens"] += entry.n_tokens
                    if len(r.tokens) - entry.n_tokens <= chunk:
                        # the unseen suffix fits one chunk: copy the
                        # stored KV into a lane slot and finish the
                        # prompt in a single admission-time chunk call
                        hits.append((r, entry))
                    else:
                        # partial match: the fill starts ``matched``
                        # tokens in instead of at zero
                        fills.append(r)
                        fill_entries.append(entry)
                    continue
                if store is not None:
                    eng._lane_stat(bucket)["prefix_misses"] += 1
                if chunk is None or len(r.tokens) <= chunk:
                    whole.append(r)
                else:
                    fills.append(r)
                    fill_entries.append(None)
            if whole:
                self._prefill(whole, lane)
            if hits:
                self._prefill_hits(hits, lane)
            if fills:
                self._begin_fills(fills, lane, entries=fill_entries)

    # ----------------------------------------------- whole-prompt prefill
    def _prefill(self, claimed, lane: _Lane) -> None:  # holds: worker
        """Prefill-into-slot: fill the new rows' KV straight into pool
        slots and emit their first token; they join the in-flight set for
        the next segment. A failure anywhere (compile error, pool
        exhaustion, ...) must not strand the claimed requests — their
        futures are already RUNNING and outside lane.rows, so run()'s
        _fail_inflight can't see them: fail them here and release any
        slots that never became rows, then keep serving."""
        try:
            self._prefill_inner(claimed, lane)
        except Exception as e:
            live = {id(row.req) for row in lane.rows.values()}
            ids = {id(r) for r in claimed}
            for slot, rid in enumerate(lane.pool.request_of):
                if rid in ids and slot not in lane.rows:
                    lane.pool.release(slot)
            for r in claimed:
                if id(r) not in live and not r.future.done():
                    r.future.set_exception(e)

    def _prefill_inner(self, claimed, lane: _Lane) -> None:  # holds: worker
        eng = self.eng
        t0 = time.perf_counter()
        B, bucket, pool = len(claimed), lane.bucket, lane.pool
        # gather acquire: one compiled variant per join size, not per slot
        # run position (joins land at arbitrary offsets mid-serve)
        slots, view = pool.acquire([id(r) for r in claimed], gather=True)
        toks = np.zeros((B, bucket), np.int32)
        lens = np.zeros(B, np.int32)
        for i, r in enumerate(claimed):
            r.t_start = t0
            toks[i, :len(r.tokens)] = r.tokens
            lens[i] = len(r.tokens)
        temp, topk, seed, eos, budget, any_sample = \
            eng._sampling_arrays(claimed)
        sargs = ((jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(seed))
                 if any_sample else (None, None, None))
        first, caches = eng._prefill_fn()(
            eng.params, jnp.asarray(toks), jnp.asarray(lens), view, *sargs)
        if eng.ec.spec_decode:
            # spec install: verify chunks attend the whole ring, so the
            # padded prefill tail (attn_apply stamps valid pos on every
            # bucket position) must go back to the empty sentinel — the
            # rollback fuses that truncation into the write-back
            pool.scatter_rollback(slots, caches, [int(x) for x in lens],
                                  lengths=[int(x) + 1 for x in lens])
            self._draft_prefill(lane, claimed, slots)
        else:
            pool.write_back(slots, caches,
                            lengths=[int(x) + 1 for x in lens])
        first = np.asarray(first)
        eng._stats["prefill_batches"] += 1
        t1 = time.perf_counter()
        for i, (r, s) in enumerate(zip(claimed, slots)):
            # whole-prompt joins can still seed the store (a prompt of
            # exactly one chunk is a storable boundary)
            self._insert_prefix(lane, r, 0, s)
            r.t_prefill_done = t1
            self._start_row(lane, r, s, int(first[i]), int(lens[i]),
                            budget=int(budget[i]), eos=int(eos[i]),
                            temp=float(temp[i]), topk=int(topk[i]),
                            seed=int(seed[i]), now=t1)

    def _start_row(self, lane: _Lane, r, slot: int, tok: int, plen: int, *,  # holds: worker
                   budget: int, eos: int, temp: float, topk: int, seed: int,
                   now: float) -> None:
        """Install a freshly prefilled request as an in-flight decode row
        (its first token already selected at the prompt's last position)."""
        row = _Row(req=r, slot=slot, toks=[tok])
        lane.rows[slot] = row
        r.handle._push([tok])
        lane.last_tok[slot] = tok
        lane.pos[slot] = plen           # first token sits at len(prompt)
        lane.budget[slot] = budget - 1  # the first token spent one
        lane.eos[slot], lane.temp[slot] = eos, temp
        lane.topk[slot], lane.seed[slot] = topk, seed
        hit = eos >= 0 and tok == eos
        if hit or lane.budget[slot] <= 0:
            self._finish(lane, row, FINISH_EOS if hit else FINISH_LENGTH,
                         now)
        else:
            lane.active[slot] = True

    # ----------------------------------------------- prefix-cache fast path
    def _prefill_hits(self, claimed, lane: _Lane) -> None:  # holds: worker
        """Admit requests whose prompt matched a stored prefix and whose
        unseen suffix fits one chunk: copy-on-reference the stored KV into
        lane slots (one fused gather/scatter) and run a single suffix
        chunk at the absolute prefix offset — the whole prompt never runs.
        ``claimed`` is a list of (request, PrefixEntry) pairs; entry refs
        are released once the copy has been issued. Failure handling
        mirrors _prefill."""
        try:
            self._prefill_hits_inner(claimed, lane)
        except Exception as e:
            live = {id(row.req) for row in lane.rows.values()}
            ids = {id(r) for r, _ in claimed}
            for slot, rid in enumerate(lane.pool.request_of):
                if rid in ids and slot not in lane.rows:
                    lane.pool.release(slot)
            for r, _ in claimed:
                if id(r) not in live and not r.future.done():
                    r.future.set_exception(e)

    def _prefill_hits_inner(self, claimed, lane: _Lane) -> None:  # holds: worker
        eng = self.eng
        store = eng._prefix_store(lane.bucket)
        C = eng.ec.prefill_chunk
        t0 = time.perf_counter()
        B, pool = len(claimed), lane.pool
        reqs = [r for r, _ in claimed]
        # claim without reset: the load overwrites the slots fully
        slots = pool.claim([id(r) for r in reqs])
        try:
            store.load_many([e for _, e in claimed], pool, slots)
        finally:
            for _, e in claimed:
                store.release(e)
        toks = np.zeros((B, C), np.int32)
        start = np.zeros(B, np.int32)
        nvalid = np.zeros(B, np.int32)
        for i, (r, e) in enumerate(claimed):
            r.t_start = t0
            suffix = np.asarray(r.tokens)[e.n_tokens:]
            toks[i, :len(suffix)] = suffix
            start[i], nvalid[i] = e.n_tokens, len(suffix)
        temp, topk, seed, eos, budget, any_sample = \
            eng._sampling_arrays(reqs)
        sargs = ((jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(seed))
                 if any_sample else (None, None, None))
        first, caches = eng._chunk_fn()(
            eng.params, jnp.asarray(toks), jnp.asarray(start),
            jnp.asarray(nvalid), pool.batch_view(slots, gather=True),
            *sargs)
        if eng.ec.spec_decode:
            # the suffix chunk's padded tail also stamps valid pos values
            # past the prompt — truncate at install (see _prefill_inner)
            pool.scatter_rollback(slots, caches,
                                  [len(r.tokens) for r in reqs],
                                  lengths=[len(r.tokens) + 1 for r in reqs])
            self._draft_prefill(lane, reqs, slots)
        else:
            pool.write_back(slots, caches,
                            lengths=[len(r.tokens) + 1 for r in reqs])
        first = np.asarray(first)
        eng._stats["prefill_batches"] += 1
        t1 = time.perf_counter()
        for i, ((r, e), s) in enumerate(zip(claimed, slots)):
            # a prompt extending >= 1 chunk past its matched prefix is a
            # new, deeper boundary worth storing (conversation growth)
            self._insert_prefix(lane, r, e.n_tokens, s)
            r.t_prefill_done = t1
            self._start_row(lane, r, s, int(first[i]), len(r.tokens),
                            budget=int(budget[i]), eos=int(eos[i]),
                            temp=float(temp[i]), topk=int(topk[i]),
                            seed=int(seed[i]), now=t1)

    def _insert_prefix(self, lane: _Lane, r, matched: int,  # holds: worker
                       slot: int) -> None:
        """Insert-on-complete: offer the finished prompt's KV (sitting in
        its lane slot) to the bucket's prefix store. ``matched`` is what
        this request itself reused — depths at or below it are already
        stored. No-op when the prefix cache is off for the bucket."""
        store = self.eng._prefix_store(lane.bucket)
        if store is None:
            return
        ins, evc = store.insert(r.tokens, matched, lane.pool, slot)
        if ins or evc:
            stat = self.eng._lane_stat(lane.bucket)
            stat["prefix_inserts"] += ins
            stat["prefix_evictions"] += evc
            stat["prefix_bytes"] = store.bytes_used

    # --------------------------------------------------- chunked prefill
    def _begin_fills(self, claimed, lane: _Lane, entries=None) -> None:  # holds: worker
        """Reserve a lane slot + a staging slot per long-prompt join; the
        prompt then advances one chunk per scheduler turn in _fill_chunk.
        ``entries[i]`` (when given) is request i's matched ``PrefixEntry``:
        its stored KV is copied into the staging slot and the fill starts
        ``matched`` tokens in — a head start on a prompt whose unseen
        suffix still spans multiple chunks. Entry refs are released here
        whatever happens. Failure handling mirrors _prefill: claimed
        futures are RUNNING, so fail them here and release both slots."""
        eng = self.eng
        store = eng._prefix_store(lane.bucket)
        if entries is None:
            entries = [None] * len(claimed)
        try:
            staging = lane.get_staging(eng)
            temp, topk, seed, eos, budget, _ = eng._sampling_arrays(claimed)
            slots = lane.pool.assign_many([id(r) for r in claimed])
            stg = staging.assign_many([id(r) for r in claimed])
            hit = [(ent, stg[i]) for i, ent in enumerate(entries)
                   if ent is not None]
            if hit:
                store.load_many([ent for ent, _ in hit], staging,
                                [s for _, s in hit])
            t0 = time.perf_counter()
            for i, r in enumerate(claimed):
                r.t_start = t0
                matched = entries[i].n_tokens if entries[i] else 0
                lane.fills.append(_Fill(
                    req=r, slot=slots[i], stg=stg[i],
                    filled=matched, matched=matched,
                    temp=float(temp[i]), topk=int(topk[i]),
                    seed=int(seed[i]), eos=int(eos[i]),
                    budget=int(budget[i])))
        except Exception as e:
            ids = {id(r) for r in claimed}
            self._release_fills(lane, [f for f in lane.fills
                                       if id(f.req) in ids])
            for pool in (lane.pool, lane.staging):
                if pool is None:
                    continue
                for slot, rid in enumerate(pool.request_of):
                    if rid in ids:
                        pool.release(slot)
            for r in claimed:
                if not r.future.done():
                    r.future.set_exception(e)
        finally:
            for ent in entries:
                if ent is not None:
                    store.release(ent)

    def _release_fills(self, lane: _Lane, fills) -> None:  # holds: worker
        for f in fills:
            if f in lane.fills:
                lane.fills.remove(f)
            lane.pool.release(f.slot)
            if lane.staging is not None:
                lane.staging.release(f.stg)

    def _fill_chunk(self, lane: _Lane) -> None:  # holds: worker
        """Advance every in-flight fill of this lane by one prompt chunk
        (one jitted call over the fill batch). Fills whose prompt completes
        are copied staging -> lane slot (one chunk-granular write_back) and
        join the decode set with their first token."""
        eng = self.eng
        now = time.perf_counter()
        for f in list(lane.fills):       # cancelled mid-prefill: retire
            h = f.req.handle
            if h is not None and h.cancel_requested:
                self._release_fills(lane, [f])
                f.req.t_prefill_done = now
                self._resolve(f.req, [], FINISH_CANCELLED, now)
        if not lane.fills:
            return
        try:
            self._fill_chunk_inner(lane)
        except Exception as e:
            fills = list(lane.fills)
            self._release_fills(lane, fills)
            for f in fills:
                if not f.req.future.done():
                    f.req.future.set_exception(e)

    def _fill_chunk_inner(self, lane: _Lane) -> None:  # holds: worker
        eng = self.eng
        C = eng.ec.prefill_chunk
        fills = list(lane.fills)
        B = len(fills)
        staging = lane.get_staging(eng)
        toks = np.zeros((B, C), np.int32)
        start = np.zeros(B, np.int32)
        nvalid = np.zeros(B, np.int32)
        temp = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        seed = np.zeros(B, np.int32)
        for i, f in enumerate(fills):
            chunk = np.asarray(f.req.tokens)[f.filled:f.filled + C]
            toks[i, :len(chunk)] = chunk
            start[i], nvalid[i] = f.filled, len(chunk)
            temp[i], topk[i], seed[i] = f.temp, f.topk, f.seed
        any_sample = bool((temp > 0).any())
        sargs = ((jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(seed))
                 if any_sample else (None, None, None))
        stg_slots = [f.stg for f in fills]
        first, caches = eng._chunk_fn()(
            eng.params, jnp.asarray(toks), jnp.asarray(start),
            jnp.asarray(nvalid), staging.batch_view(stg_slots, gather=True),
            *sargs)
        staging.write_back(
            stg_slots, caches,
            lengths=[f.filled + int(nvalid[i])
                     for i, f in enumerate(fills)])
        first = np.asarray(first)
        eng._stats["prefill_chunks"] += B
        eng._lane_stat(lane.bucket)["prefill_chunks"] += B
        done = []
        for i, f in enumerate(fills):
            f.filled += int(nvalid[i])
            if f.filled >= len(f.req.tokens):
                done.append((i, f))
        if not done:
            return
        t1 = time.perf_counter()
        # one scatter installs every completed prompt into its lane slot
        done_slots = [f.slot for _, f in done]
        src = staging.batch_view([f.stg for _, f in done], gather=True)
        if eng.ec.spec_decode:
            # the last staged chunk's padded tail carries valid pos values
            # past the prompt — truncate at install (see _prefill_inner)
            lane.pool.scatter_rollback(
                done_slots, src, [f.filled for _, f in done],
                lengths=[f.filled + 1 for _, f in done])
            self._draft_prefill(lane, [f.req for _, f in done], done_slots)
        else:
            lane.pool.write_back(
                done_slots, src, lengths=[f.filled + 1 for _, f in done])
        for i, f in done:
            lane.fills.remove(f)
            staging.release(f.stg)
            self._insert_prefix(lane, f.req, f.matched, f.slot)
            f.req.t_prefill_done = t1
            self._start_row(lane, f.req, f.slot, int(first[i]), f.filled,
                            budget=f.budget, eos=f.eos, temp=f.temp,
                            topk=f.topk, seed=f.seed, now=t1)

    # ------------------------------------------------------ decode steps
    def _segment(self, lane: _Lane) -> None:  # holds: worker
        """One decode segment for a lane, at the smallest width tier that
        fits its live occupancy (``segment_width='adaptive'``; 'fixed'
        degenerates the ladder to ``max_batch`` and always takes the
        full-width path). Both paths return per-row results aligned with
        ``slots``; the retire loop below is shared."""
        eng = self.eng
        occ = len(lane.rows)
        width = pick_tier(occ, eng._tiers)
        stat = eng._lane_stat(lane.bucket)
        if width >= eng.ec.max_batch:
            width = eng.ec.max_batch
            slots, toks, emits, st_active, st_eos = self._segment_full(lane)
        else:
            slots, toks, emits, st_active, st_eos = \
                self._segment_compact(lane, width)
            stat["compact_segments"] += 1
        eng.batch_sizes.append(occ)              # per-segment occupancy
        eng._stats["decode_segments"] += 1
        stat["decode_segments"] += 1
        stat["occupancy_sum"] += occ
        stat["tier_hist"][width] += 1    # key pre-created per tier
        #                                  (metrics() iterates lock-free)
        now = time.perf_counter()
        pool = lane.pool
        for j, s in enumerate(slots):
            row = lane.rows[s]
            new = toks[j][emits[j]].tolist()
            row.toks.extend(new)
            row.req.handle._push(new)
            pool.lengths[s] = int(lane.pos[s]) + 1
            if not st_active[j]:
                self._finish(lane, row,
                             FINISH_EOS if st_eos[j] else FINISH_LENGTH, now)
            elif row.req.handle.cancel_requested:
                self._finish(lane, row, FINISH_CANCELLED, now)

    def _segment_full(self, lane: _Lane):  # holds: worker
        """Full-width segment over every pool slot (live rows plus inert
        free/prefilling slots) — today's fixed-width path, and the adaptive
        path's top tier. The pool caches are donated and swapped whole."""
        eng = self.eng
        pool = lane.pool
        any_sample = any(lane.temp[s] > 0 for s in lane.rows)
        sargs = ((jnp.asarray(lane.temp), jnp.asarray(lane.topk),
                  jnp.asarray(lane.seed)) if any_sample
                 else (None, None, None))
        toks, emits, state, caches = eng._segment_fn()(
            eng.params, jnp.asarray(lane.last_tok[:, None]),
            jnp.asarray(lane.pos[:, None]), pool.caches,
            jnp.asarray(lane.active), jnp.asarray(lane.budget),
            jnp.asarray(lane.eos), *sargs)
        pool.caches = caches
        toks, emits = np.asarray(toks), np.asarray(emits)
        st_active = np.asarray(state["active"])
        st_eos = np.asarray(state["eos_hit"])
        lane.last_tok = np.asarray(state["tok"])[:, 0].copy()
        lane.pos = np.asarray(state["pos"])[:, 0].copy()
        lane.budget = np.asarray(state["budget"]).copy()
        lane.active = st_active.copy()
        slots = list(lane.rows)
        return (slots, toks[slots], emits[slots], st_active[slots],
                st_eos[slots])

    def _segment_compact(self, lane: _Lane, width: int):  # holds: worker
        """Compacted segment: gather the live rows (and their decode
        state) into a ``width``-row view, decode at that width, scatter
        the live prefix back to the home slots. View rows past the
        occupancy are duplicates of ``slots[0]`` that ride along inactive
        and are never scattered back, so pool slots outside ``slots`` —
        free, prefilling, or mid-retire — keep their KV and state bitwise
        (tested as a round-trip property)."""
        eng = self.eng
        pool = lane.pool
        slots = sorted(lane.rows)         # deterministic gather order
        occ = len(slots)
        # idx is the view's gather order (slots + padding duplicates);
        # state rows are gathered by the same idx so row j of the state
        # always describes row j of the cache view
        idx, view = pool.compact_view(slots, width)
        act = lane.active[idx].copy()
        act[occ:] = False                 # padding rows are inert
        any_sample = any(lane.temp[s] > 0 for s in slots)
        sargs = ((jnp.asarray(lane.temp[idx]), jnp.asarray(lane.topk[idx]),
                  jnp.asarray(lane.seed[idx])) if any_sample
                 else (None, None, None))
        toks, emits, state, caches = eng._segment_fn()(
            eng.params, jnp.asarray(lane.last_tok[idx][:, None]),
            jnp.asarray(lane.pos[idx][:, None]), view,
            jnp.asarray(act), jnp.asarray(lane.budget[idx]),
            jnp.asarray(lane.eos[idx]), *sargs)
        pool.scatter_back(slots, caches)
        toks, emits = np.asarray(toks)[:occ], np.asarray(emits)[:occ]
        st_active = np.asarray(state["active"])[:occ]
        st_eos = np.asarray(state["eos_hit"])[:occ]
        lane.last_tok[slots] = np.asarray(state["tok"])[:occ, 0]
        lane.pos[slots] = np.asarray(state["pos"])[:occ, 0]
        lane.budget[slots] = np.asarray(state["budget"])[:occ]
        lane.active[slots] = st_active
        return slots, toks, emits, st_active, st_eos

    # ------------------------------------------------- speculative rounds
    def _draft_prefill(self, lane: _Lane, reqs, slots) -> None:  # holds: worker
        """Whole-prompt prefill of the draft model's KV for newly admitted
        rows, into the draft pool at the rows' lane slot indices. The
        draft always sees the full prompt in one call — prompts fit the
        bucket by construction and the draft has no prefix store —
        whichever path (whole, prefix-hit, chunked fill) admitted the row
        on the target side; the rollback wipes the padded tail exactly
        like the target install's. The draft pool bypasses slot
        bookkeeping (no claim/release): slot liveness is the lane pool's."""
        eng = self.eng
        dpool = eng._get_draft_pool(lane.bucket)
        B, bucket = len(reqs), lane.bucket
        toks = np.zeros((B, bucket), np.int32)
        lens = np.zeros(B, np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.tokens)] = r.tokens
            lens[i] = len(r.tokens)
        sl = jnp.asarray(list(slots), jnp.int32)
        dpool.caches, dview = kvcache._reset_and_view(
            dpool.caches, dpool._template, sl)
        dcaches = eng._draft_prefill_fn()(eng.draft_params,
                                          jnp.asarray(toks), dview)
        dpool.caches = kvcache._scatter_rollback(
            dpool.caches, dcaches, sl, jnp.asarray(lens))

    def _spec_round(self, lane: _Lane) -> None:  # holds: worker
        """One draft-and-verify round for a lane's live rows — the spec
        engine's replacement for ``_segment``. Always the compacted gather
        path (``segment_width='fixed'`` just pins the tier ladder to
        max_batch), so untouched pool slots stay bitwise identical: the
        round runs on a tier-width *view* of both pools and only the live
        prefix is scattered home, each row truncated to its own commit
        boundary. Per-row committed counts (1..spec_k+1) desynchronize the
        rows' positions — which plain per-slot segments never do — and the
        rollback is what re-establishes, for both pools, the invariant
        that positions at or past a row's frontier hold the empty
        sentinel before the next round reads them."""
        eng = self.eng
        k = eng.ec.spec_k
        pool, dpool = lane.pool, eng._get_draft_pool(lane.bucket)
        slots = sorted(lane.rows)         # deterministic gather order
        occ = len(slots)
        width = pick_tier(occ, eng._tiers)
        idx, view = pool.compact_view(slots, width)
        _, dview = dpool.compact_view(slots, width)
        any_sample = any(lane.temp[s] > 0 for s in slots)
        sargs = ((jnp.asarray(lane.temp[idx]), jnp.asarray(lane.topk[idx]),
                  jnp.asarray(lane.seed[idx])) if any_sample
                 else (None, None, None))
        drafts, verify, caches, dcaches = eng._spec_round_fn()(
            eng.params, eng.draft_params,
            jnp.asarray(lane.last_tok[idx][:, None]),
            jnp.asarray(lane.pos[idx][:, None]), view, dview, *sargs)
        drafts = np.asarray(drafts)[:occ]         # (occ, k) proposals
        verify = np.asarray(verify)[:occ]         # (occ, k+1) target picks
        stat = eng._lane_stat(lane.bucket)
        eng.batch_sizes.append(occ)
        eng._stats["decode_segments"] += 1
        stat["decode_segments"] += 1
        stat["occupancy_sum"] += occ
        stat["tier_hist"][width] += 1
        stat["spec_rounds"] += 1
        stat["spec_proposed"] += occ * k
        now = time.perf_counter()
        bounds = np.zeros(occ, np.int32)
        retire = []
        for j, s in enumerate(slots):
            row = lane.rows[s]
            a = 0                  # leading draft tokens the target agreed on
            while a < k and drafts[j, a] == verify[j, a]:
                a += 1
            stat["spec_accepted"] += a
            # commit the agreements plus one target-selected token (the
            # correction at the first disagreement, or the bonus token
            # after a full accept), clamped to the row's budget
            c = min(a + 1, int(lane.budget[s]))
            committed = verify[j, :c].tolist()
            eos, eos_hit = int(lane.eos[s]), False
            if eos >= 0 and eos in committed:
                committed = committed[:committed.index(eos) + 1]
                eos_hit = True
            c = len(committed)
            bounds[j] = int(lane.pos[s]) + c
            lane.last_tok[s] = committed[-1]
            lane.pos[s] = bounds[j]
            lane.budget[s] -= c
            row.toks.extend(committed)
            row.req.handle._push(committed)
            pool.lengths[s] = int(bounds[j]) + 1
            if eos_hit:
                retire.append((row, FINISH_EOS))
            elif lane.budget[s] <= 0:
                retire.append((row, FINISH_LENGTH))
            elif row.req.handle.cancel_requested:
                retire.append((row, FINISH_CANCELLED))
        # scatter before retiring (like _segment_compact): _finish releases
        # slots, and a released slot must not be written afterwards
        pool.scatter_rollback(slots, caches, bounds)
        dpool.scatter_rollback(slots, dcaches, bounds)
        for row, reason in retire:
            self._finish(lane, row, reason, now)

    # ------------------------------------------------------------ retire
    def _resolve(self, r, toks, reason: str, now: float) -> None:  # holds: worker
        eng = self.eng
        timing = RequestTiming(queue_s=r.t_start - r.t_submit,
                               prefill_s=r.t_prefill_done - r.t_start,
                               decode_s=now - r.t_prefill_done)
        eng.timings.append(timing)
        eng.latencies.append(now - r.t_submit)
        r.future.set_result(GenerationResult(
            tokens=np.asarray(toks, np.int32), finish_reason=reason,
            timing=timing, request_id=r.handle.request.request_id))

    def _finish(self, lane: _Lane, row: _Row, reason: str,  # holds: worker
                now: float) -> None:
        del lane.rows[row.slot]
        lane.pool.release(row.slot)
        lane.active[row.slot] = False
        self._resolve(row.req, row.toks, reason, now)

    def _fail_inflight(self, exc: Exception) -> None:  # holds: worker
        for lane in self.lanes.values():
            for row in list(lane.rows.values()):
                del lane.rows[row.slot]
                lane.pool.release(row.slot)
                lane.active[row.slot] = False
                if not row.req.future.done():
                    row.req.future.set_exception(exc)
            fills = list(lane.fills)
            self._release_fills(lane, fills)
            for f in fills:
                if not f.req.future.done():
                    f.req.future.set_exception(exc)

    def _shutdown(self) -> None:  # holds: worker
        err = RuntimeError("engine is closed")
        self._fail_inflight(err)
        for r in self.pending.drain():
            if not r.future.done():
                r.future.set_exception(err)
