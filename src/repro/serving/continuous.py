"""Step-driven continuous-batching scheduler — the decoder worker that
closes the ROADMAP's "continuous batching at step granularity" item.

The batch-at-a-time worker serves a batch start-to-finish: a request
arriving one step after a batch launches waits the batch's whole decode
(the head-of-line blowup behind the paper's Tables 2-4 latency cliff).
This scheduler instead drives decode in short jitted scan segments
(``EngineConfig.decode_segment`` steps of ``models.decode_segment``) over a
fixed batch of ``CachePool`` slots, and between segments — a host sync it
needs anyway to stream tokens — it:

  * retires rows that finished in-graph (per-row eos / budget stop),
    releasing their pool slot and resolving their future with a
    ``GenerationResult`` (finish_reason + queue/prefill/decode timing);
  * retires rows whose client cancelled mid-decode;
  * admits the best pending requests (priority order, FIFO within a
    level) into free slots via prefill-into-slot: one jitted prefill fills
    the new rows' KV straight into the pool (``CachePool.write_back``) and
    selects their first token, after which they ride the same segments as
    the rows already in flight.

Rows in one in-flight set share a pad bucket (one pool / one compiled
segment shape); when the set drains, the next bucket is chosen from the
best pending request. Inactive slots cost compute (the segment always runs
the full slot batch — static shapes keep it one compiled function) but re-
write their frozen KV slot idempotently, so correctness never depends on
occupancy. Per-segment occupancy lands in ``engine.batch_sizes`` and the
join/segment counters in ``engine.metrics()``.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.api import (FINISH_CANCELLED, FINISH_EOS, FINISH_LENGTH,
                               GenerationResult, RequestTiming)
from repro.serving.scheduler import RequestQueue


@dataclasses.dataclass
class _Row:
    req: "object"                    # engine._Request
    slot: int
    toks: List[int] = dataclasses.field(default_factory=list)


class ContinuousScheduler:
    def __init__(self, engine):
        self.eng = engine
        n = engine.ec.max_batch
        self.last_tok = np.zeros(n, np.int32)   # token each row just made
        self.pos = np.zeros(n, np.int32)        # its absolute position
        self.active = np.zeros(n, bool)
        self.budget = np.zeros(n, np.int32)     # tokens left to emit
        self.eos = np.full(n, -1, np.int32)
        self.temp = np.zeros(n, np.float32)
        self.topk = np.zeros(n, np.int32)
        self.seed = np.zeros(n, np.int32)
        self.rows = {}                          # slot -> _Row
        self.bucket: Optional[int] = None       # in-flight set's pad bucket
        self.pending = RequestQueue()

    # ------------------------------------------------------------ worker
    def run(self):
        eng = self.eng
        try:
            while not eng._stop.is_set():
                try:
                    self._drain(block=not self.rows and not self.pending)
                    self._admit()
                    if self.rows:
                        self._segment()
                except Exception as e:  # surfaced to the affected clients
                    self._fail_inflight(e)
        finally:
            self._shutdown()

    def _drain(self, block: bool) -> None:
        """Move newly submitted requests into the priority-pending set;
        when idle, block briefly so the loop doesn't spin."""
        try:
            while True:
                req = (self.eng._q.get(timeout=0.05) if block
                       else self.eng._q.get_nowait())
                block = False
                self.pending.push(req, req.priority)
        except queue.Empty:
            pass

    # --------------------------------------------------------- admission
    def _admit(self) -> None:
        eng = self.eng
        if not self.pending:
            return
        drop = lambda r: r.future.done()    # noqa: E731 — cancelled in queue
        claimed = []
        if not self.rows:
            # set drained: the best pending request picks the next bucket
            first = self.pending.pop(drop=drop)
            if first is None:
                return
            self.bucket = eng._bucket(len(first.tokens))
            claimed.append(first)
        pool = eng._get_pool(self.bucket)
        in_bucket = lambda r: eng._bucket(len(r.tokens)) == self.bucket  # noqa: E731
        while pool.free_slots > len(claimed):
            r = self.pending.pop(pred=in_bucket, drop=drop)
            if r is None:
                break
            claimed.append(r)
        claimed = [r for r in claimed
                   if r.future.set_running_or_notify_cancel()]
        if not claimed:
            return
        if self.rows:
            eng._stats["joins_mid_flight"] += len(claimed)
        self._prefill(claimed, pool)

    def _prefill(self, claimed, pool) -> None:
        """Prefill-into-slot: fill the new rows' KV straight into pool
        slots and emit their first token; they join the in-flight set for
        the next segment. A failure anywhere (compile error, pool
        exhaustion, ...) must not strand the claimed requests — their
        futures are already RUNNING and outside self.rows, so run()'s
        _fail_inflight can't see them: fail them here and release any
        slots that never became rows, then keep serving."""
        try:
            self._prefill_inner(claimed, pool)
        except Exception as e:
            live = {id(row.req) for row in self.rows.values()}
            for slot, rid in enumerate(pool.request_of):
                if rid in {id(r) for r in claimed} and slot not in self.rows:
                    pool.release(slot)
            for r in claimed:
                if id(r) not in live and not r.future.done():
                    r.future.set_exception(e)

    def _prefill_inner(self, claimed, pool) -> None:
        eng = self.eng
        t0 = time.perf_counter()
        B, bucket = len(claimed), self.bucket
        # gather acquire: one compiled variant per join size, not per slot
        # run position (joins land at arbitrary offsets mid-serve)
        slots, view = pool.acquire([id(r) for r in claimed], gather=True)
        toks = np.zeros((B, bucket), np.int32)
        lens = np.zeros(B, np.int32)
        for i, r in enumerate(claimed):
            r.t_start = t0
            toks[i, :len(r.tokens)] = r.tokens
            lens[i] = len(r.tokens)
        temp, topk, seed, eos, budget, any_sample = \
            eng._sampling_arrays(claimed)
        sargs = ((jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(seed))
                 if any_sample else (None, None, None))
        first, caches = eng._prefill_fn()(
            eng.params, jnp.asarray(toks), jnp.asarray(lens), view, *sargs)
        pool.write_back(slots, caches, lengths=[int(x) + 1 for x in lens])
        first = np.asarray(first)
        eng._stats["prefill_batches"] += 1
        t1 = time.perf_counter()
        for i, (r, s) in enumerate(zip(claimed, slots)):
            r.t_prefill_done = t1
            tok = int(first[i])
            row = _Row(req=r, slot=s, toks=[tok])
            self.rows[s] = row
            r.handle._push([tok])
            self.last_tok[s] = tok
            self.pos[s] = lens[i]           # first token sits at len(prompt)
            self.budget[s] = budget[i] - 1  # the first token spent one
            self.eos[s], self.temp[s] = eos[i], temp[i]
            self.topk[s], self.seed[s] = topk[i], seed[i]
            hit = eos[i] >= 0 and tok == eos[i]
            if hit or self.budget[s] <= 0:
                self._finish(row, FINISH_EOS if hit else FINISH_LENGTH, t1)
            else:
                self.active[s] = True

    # ------------------------------------------------------ decode steps
    def _segment(self) -> None:
        eng = self.eng
        pool = eng._get_pool(self.bucket)
        any_sample = any(self.temp[s] > 0 for s in self.rows)
        sargs = ((jnp.asarray(self.temp), jnp.asarray(self.topk),
                  jnp.asarray(self.seed)) if any_sample
                 else (None, None, None))
        toks, emits, state, caches = eng._segment_fn()(
            eng.params, jnp.asarray(self.last_tok[:, None]),
            jnp.asarray(self.pos[:, None]), pool.caches,
            jnp.asarray(self.active), jnp.asarray(self.budget),
            jnp.asarray(self.eos), *sargs)
        pool.caches = caches
        toks, emits = np.asarray(toks), np.asarray(emits)
        st_active = np.asarray(state["active"])
        st_eos = np.asarray(state["eos_hit"])
        self.last_tok = np.asarray(state["tok"])[:, 0].copy()
        self.pos = np.asarray(state["pos"])[:, 0].copy()
        self.budget = np.asarray(state["budget"]).copy()
        self.active = st_active.copy()
        eng.batch_sizes.append(len(self.rows))   # per-segment occupancy
        eng._stats["decode_segments"] += 1
        now = time.perf_counter()
        for s, row in list(self.rows.items()):
            new = toks[s][emits[s]].tolist()
            row.toks.extend(new)
            row.req.handle._push(new)
            pool.lengths[s] = int(self.pos[s]) + 1
            if not st_active[s]:
                self._finish(row, FINISH_EOS if st_eos[s] else FINISH_LENGTH,
                             now)
            elif row.req.handle.cancel_requested:
                self._finish(row, FINISH_CANCELLED, now)

    # ------------------------------------------------------------ retire
    def _finish(self, row: _Row, reason: str, now: float) -> None:
        eng = self.eng
        r = row.req
        del self.rows[row.slot]
        eng._get_pool(self.bucket).release(row.slot)
        self.active[row.slot] = False
        timing = RequestTiming(queue_s=r.t_start - r.t_submit,
                               prefill_s=r.t_prefill_done - r.t_start,
                               decode_s=now - r.t_prefill_done)
        eng.timings.append(timing)
        eng.latencies.append(now - r.t_submit)
        r.future.set_result(GenerationResult(
            tokens=np.asarray(row.toks, np.int32), finish_reason=reason,
            timing=timing, request_id=r.handle.request.request_id))

    def _fail_inflight(self, exc: Exception) -> None:
        for row in list(self.rows.values()):
            del self.rows[row.slot]
            self.eng._get_pool(self.bucket).release(row.slot)
            self.active[row.slot] = False
            if not row.req.future.done():
                row.req.future.set_exception(exc)

    def _shutdown(self) -> None:
        err = RuntimeError("engine is closed")
        self._fail_inflight(err)
        for r in self.pending.drain():
            if not r.future.done():
                r.future.set_exception(err)
