from repro.serving.engine import (EngineConfig, RequestTooLong,  # noqa: F401
                                  ServingEngine)
from repro.serving.kvcache import CachePool  # noqa: F401
from repro.serving.scheduler import AdmissionQueue  # noqa: F401
