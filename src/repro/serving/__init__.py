from repro.serving.api import (FINISH_CANCELLED, FINISH_EOS,  # noqa: F401
                               FINISH_LENGTH, GenerationRequest,
                               GenerationResult, HeadFn, RequestHandle,
                               RequestTiming, SamplingParams, collect)
from repro.serving.engine import (EngineConfig, RequestTooLong,  # noqa: F401
                                  ServingEngine)
from repro.serving.kvcache import CachePool  # noqa: F401
from repro.serving.scheduler import AdmissionQueue, RequestQueue  # noqa: F401
