from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.scheduler import AdmissionQueue  # noqa: F401
