"""Admission-control queue — the mitigation the paper *proposes* in §4
("create a queue in the application layer to control submission flow taking
this processing threshold into account") but does not implement.

We implement it: a bounded in-flight window with FIFO overflow queueing.
Under overload the paper's Flask setup lets every request contend (latency
blows up superlinearly, their Tables 2–4 above the red line); with admission
control, excess requests wait in queue and in-flight work stays at the
throughput-optimal concurrency, so p50 service latency stays flat and only
queue wait grows linearly. examples/serve_poc.py measures both modes.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import List


class RequestQueue:
    """Priority-aware request ordering (admission overflow + the continuous
    scheduler's pending set): pop returns the highest-priority entry, FIFO
    within a priority level. Not thread-safe — callers hold the engine's
    submit lock (overflow) or own the worker thread (pending)."""

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def push(self, item, priority: int = 0) -> None:
        heapq.heappush(self._heap, (-priority, next(self._seq), item))

    def pop(self, pred=None, drop=None):
        """Pop the best item for which ``pred`` holds (default: any).
        Entries matching ``drop`` (e.g. requests cancelled while queued)
        are discarded during the scan; entries failing ``pred`` are kept.
        Returns None when no item qualifies."""
        kept, best = [], None
        while self._heap:
            entry = heapq.heappop(self._heap)
            if drop is not None and drop(entry[2]):
                continue
            if pred is None or pred(entry[2]):
                best = entry[2]
                break
            kept.append(entry)
        for entry in kept:
            heapq.heappush(self._heap, entry)
        return best

    def drain(self) -> List:
        items = [e[2] for e in sorted(self._heap)]
        self._heap.clear()
        return items

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


@dataclass
class AdmissionStats:
    admitted: int = 0
    queued_peak: int = 0
    wait_total_s: float = 0.0


class AdmissionQueue:
    def __init__(self, max_inflight: int):
        self.max_inflight = max_inflight
        self._sem = threading.Semaphore(max_inflight)
        self._lock = threading.Lock()
        self._waiting = 0
        self.stats = AdmissionStats()

    def acquire(self) -> None:
        """Block until an in-flight slot is free (FIFO-ish via semaphore)."""
        t0 = time.perf_counter()
        with self._lock:
            self._waiting += 1
            self.stats.queued_peak = max(self.stats.queued_peak,
                                         self._waiting)
        self._sem.acquire()
        with self._lock:
            self._waiting -= 1
            self.stats.admitted += 1
            self.stats.wait_total_s += time.perf_counter() - t0

    def try_acquire(self) -> bool:
        """Non-blocking admission — the engine's submit path: a free slot
        admits immediately; otherwise the caller parks the request on an
        overflow queue (no dispatcher thread, no blocked submitter) and
        reports its depth via note_queued/admit_transfer."""
        if not self._sem.acquire(blocking=False):
            return False
        with self._lock:
            self.stats.admitted += 1
        return True

    def note_queued(self, depth: int) -> None:
        """Record the overflow-queue depth (server-side queueing stat)."""
        with self._lock:
            self.stats.queued_peak = max(self.stats.queued_peak, depth)

    def admit_transfer(self, waited_s: float) -> None:
        """A finishing request handed its slot straight to a queued one."""
        with self._lock:
            self.stats.admitted += 1
            self.stats.wait_total_s += waited_s

    def release(self) -> None:
        self._sem.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False
