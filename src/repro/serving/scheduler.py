"""Admission-control queue — the mitigation the paper *proposes* in §4
("create a queue in the application layer to control submission flow taking
this processing threshold into account") but does not implement.

We implement it: a bounded in-flight window with FIFO overflow queueing.
Under overload the paper's Flask setup lets every request contend (latency
blows up superlinearly, their Tables 2–4 above the red line); with admission
control, excess requests wait in queue and in-flight work stays at the
throughput-optimal concurrency, so p50 service latency stays flat and only
queue wait grows linearly. examples/serve_poc.py measures both modes.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field, replace
from typing import List, Sequence, Tuple


# --------------------------------------------------- segment-width policy
def width_tiers(max_batch: int) -> Tuple[int, ...]:
    """The ladder of decode-segment widths a lane may run: powers of two
    up to (and always including) ``max_batch`` — e.g. 8 -> (1, 2, 4, 8),
    6 -> (1, 2, 4, 6). Each tier is one compiled ``decode_segment``
    specialization, so the ladder bounds compile count at
    O(log max_batch) while keeping batch waste under 2x of occupancy."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    tiers = []
    w = 1
    while w < max_batch:
        tiers.append(w)
        w *= 2
    tiers.append(max_batch)
    return tuple(tiers)


def pick_tier(occupancy: int, tiers: Sequence[int]) -> int:
    """Smallest tier that fits ``occupancy`` live rows (the width the
    scheduler compacts the next decode segment to)."""
    for w in tiers:
        if occupancy <= w:
            return w
    return tiers[-1]


class RequestQueue:
    """Priority-aware request ordering (admission overflow + the continuous
    scheduler's pending set): pop returns the highest-priority entry, FIFO
    within a priority level. Not thread-safe — callers hold the engine's
    submit lock (overflow) or own the worker thread (pending)."""

    def __init__(self):
        self._heap: list = []            # guarded-by: external
        self._seq = itertools.count()    # guarded-by: external

    def push(self, item, priority: int = 0) -> None:
        heapq.heappush(self._heap, (-priority, next(self._seq), item))

    def pop(self, pred=None, drop=None):
        """Pop the best item for which ``pred`` holds (default: any).
        Entries matching ``drop`` (e.g. requests cancelled while queued)
        are discarded during the scan; entries failing ``pred`` are kept.
        Returns None when no item qualifies."""
        kept, best = [], None
        while self._heap:
            entry = heapq.heappop(self._heap)
            if drop is not None and drop(entry[2]):
                continue
            if pred is None or pred(entry[2]):
                best = entry[2]
                break
            kept.append(entry)
        for entry in kept:
            heapq.heappush(self._heap, entry)
        return best

    def peek_key(self, drop=None):
        """(-priority, seq) of the best live entry, discarding ``drop``
        matches from the top; None when empty. Lets a multi-lane scheduler
        compare lane heads without popping."""
        while self._heap:
            if drop is not None and drop(self._heap[0][2]):
                heapq.heappop(self._heap)
                continue
            return self._heap[0][:2]
        return None

    def drain(self) -> List:
        items = [e[2] for e in sorted(self._heap)]
        self._heap.clear()
        return items

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class LaneQueue:
    """Pending requests partitioned by scheduling lane (pad bucket).

    The single-set scheduler kept one shared heap and popped with a
    bucket predicate — an O(pending) pop/push rescan every segment while
    requests for *other* buckets sat in the heap. Keying a ``RequestQueue``
    per lane makes the per-lane pop O(log n_lane) and gives the multi-lane
    scheduler its admission view: which lanes have work, and which lane
    holds the globally best request (priority order, FIFO within a level,
    consistent across lanes via the shared sequence counter). Not
    thread-safe — owned by the scheduler worker thread."""

    def __init__(self):
        self._lanes: dict = {}           # guarded-by: external — lane -> RequestQueue
        self._seq = itertools.count()    # guarded-by: external — cross-lane FIFO

    def push(self, item, priority: int = 0, *, lane) -> None:
        q = self._lanes.get(lane)
        if q is None:
            q = self._lanes[lane] = RequestQueue()
            q._seq = self._seq           # one counter across all lanes
        q.push(item, priority)

    def pop(self, lane, drop=None):
        q = self._lanes.get(lane)
        return q.pop(drop=drop) if q is not None else None

    def lanes(self) -> List:
        """Lane keys that currently hold entries (insertion order)."""
        return [k for k, q in self._lanes.items() if q]

    def lane_len(self, lane) -> int:
        q = self._lanes.get(lane)
        return len(q) if q is not None else 0

    def best_lane(self, drop=None):
        """The lane whose head is the globally best pending request."""
        best_key, best_lane = None, None
        for lane, q in self._lanes.items():
            key = q.peek_key(drop=drop)
            if key is not None and (best_key is None or key < best_key):
                best_key, best_lane = key, lane
        return best_lane

    def drain(self) -> List:
        items = []
        for q in self._lanes.values():
            items.extend(q.drain())
        return items

    def __len__(self) -> int:
        return sum(len(q) for q in self._lanes.values())

    def __bool__(self) -> bool:
        return any(self._lanes.values())


@dataclass
class AdmissionStats:
    admitted: int = 0
    queued_peak: int = 0
    wait_total_s: float = 0.0


class AdmissionQueue:
    def __init__(self, max_inflight: int):
        self.max_inflight = max_inflight  # guarded-by: init
        self._sem = threading.Semaphore(max_inflight)  # guarded-by: threadsafe
        self._lock = threading.Lock()     # guarded-by: threadsafe
        self._waiting = 0                 # guarded-by: _lock
        self.stats = AdmissionStats()     # guarded-by: _lock

    def acquire(self) -> None:
        """Block until an in-flight slot is free (FIFO-ish via semaphore)."""
        t0 = time.perf_counter()
        with self._lock:
            self._waiting += 1
            self.stats.queued_peak = max(self.stats.queued_peak,
                                         self._waiting)
        self._sem.acquire()
        with self._lock:
            self._waiting -= 1
            self.stats.admitted += 1
            self.stats.wait_total_s += time.perf_counter() - t0

    def try_acquire(self) -> bool:
        """Non-blocking admission — the engine's submit path: a free slot
        admits immediately; otherwise the caller parks the request on an
        overflow queue (no dispatcher thread, no blocked submitter) and
        reports its depth via note_queued/admit_transfer."""
        if not self._sem.acquire(blocking=False):
            return False
        with self._lock:
            self.stats.admitted += 1
        return True

    def note_queued(self, depth: int) -> None:
        """Record the overflow-queue depth (server-side queueing stat)."""
        with self._lock:
            self.stats.queued_peak = max(self.stats.queued_peak, depth)

    def admit_transfer(self, waited_s: float) -> None:
        """A finishing request handed its slot straight to a queued one."""
        with self._lock:
            self.stats.admitted += 1
            self.stats.wait_total_s += waited_s

    def snapshot(self) -> AdmissionStats:
        """Consistent copy of the admission counters — the lock-safe way
        for ``engine.metrics()`` (a client thread) to read them."""
        with self._lock:
            return replace(self.stats)

    def release(self) -> None:
        self._sem.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False
