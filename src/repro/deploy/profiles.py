"""Executable environment profiles — the single source of truth for the
paper's provider x machine matrix (Table 1 specs + Table 5 prices).

Before this module existed the machine specs and prices lived as literals
inside ``core.environments`` (and the cost arithmetic re-derived hourly
prices on its own); now ``core.environments.INSTANCES`` is a re-export of
``PROFILES`` and every consumer — the static cost model, the live
experiment runner, the drift report — prices a machine through exactly one
record. A profile is *executable* in the deployment-lab sense: the runner
binds one to an engine run and the record carries its specs + hourly price
so measured throughput converts to $/1M sentences per profile.

One beyond-paper row (TPU/T) is kept for cost comparison; it is excluded
from all paper-claim validations (``paper_profiles()``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

NS_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
LATENCY_SLO_S = 2.0                 # the paper's acceptability threshold
HOURS_PER_MONTH = 730.0             # the pricing convention behind Table 5

PROVIDERS = ("AWS", "GCP", "Azure")
MACHINES = tuple("ABCDEFG")


@dataclasses.dataclass(frozen=True)
class EnvironmentProfile:
    """One provider x machine execution environment (paper Table 1 + 5)."""
    provider: str
    machine: str                    # class letter A..G (T = beyond-paper)
    instance_type: str
    processor: str
    clock_ghz: float
    vcpus: int
    cache_gb: Optional[float]       # L2+L3; None for GPU machines (unlisted)
    ram_gb: int
    gpu: Optional[str]
    monthly_cost_usd: float

    @property
    def key(self) -> str:
        return f"{self.provider}/{self.machine}"

    @property
    def hourly_cost_usd(self) -> float:
        return self.monthly_cost_usd / HOURS_PER_MONTH

    @property
    def is_gpu(self) -> bool:
        return self.gpu is not None

    def spec_dict(self) -> dict:
        """The record-schema view the experiment runner embeds in JSONL."""
        return {"provider": self.provider, "machine": self.machine,
                "instance_type": self.instance_type,
                "processor": self.processor, "clock_ghz": self.clock_ghz,
                "vcpus": self.vcpus, "cache_gb": self.cache_gb,
                "ram_gb": self.ram_gb, "gpu": self.gpu,
                "monthly_cost_usd": self.monthly_cost_usd,
                "hourly_cost_usd": self.hourly_cost_usd}


PROFILES: Tuple[EnvironmentProfile, ...] = (
    # ---- AWS ----
    EnvironmentProfile("AWS", "A", "c6a.xlarge", "AMD EPYC 7R13",
                       2.95, 4, 2, 8, None, 110.16),
    EnvironmentProfile("AWS", "B", "c6a.2xlarge", "AMD EPYC 7R13",
                       2.95, 8, 2, 16, None, 220.32),
    EnvironmentProfile("AWS", "C", "t2.xlarge", "Intel Xeon Scalable",
                       3.3, 4, 4, 16, None, 133.63),
    EnvironmentProfile("AWS", "D", "inf1.xlarge",
                       "Intel Xeon Platinum 8275CL", 3.0, 4, 2, 8, None,
                       164.16),
    EnvironmentProfile("AWS", "E", "inf1.2xlarge",
                       "Intel Xeon Platinum 8275CL", 3.0, 8, 2, 16, None,
                       260.64),
    EnvironmentProfile("AWS", "F", "g4dn.xlarge",
                       "Intel Xeon Platinum 8259CL", 2.5, 4, None, 16,
                       "NVIDIA T4", 378.72),
    EnvironmentProfile("AWS", "G", "g4dn.2xlarge",
                       "Intel Xeon Platinum 8259CL", 2.5, 8, None, 32,
                       "NVIDIA T4", 541.44),
    # ---- GCP ----
    EnvironmentProfile("GCP", "A", "n2d-custom-4-8192",
                       "AMD EPYC Milan 7B13", 3.5, 4, 2, 8, None, 100.44),
    EnvironmentProfile("GCP", "B", "n2d-custom-8-16384",
                       "AMD EPYC Milan 7B13", 3.5, 8, 2, 16, None, 200.87),
    EnvironmentProfile("GCP", "C", "n2-custom-8-16384",
                       "Intel Xeon Gold 6268CL", 3.9, 4, 4, 16, None,
                       230.89),
    EnvironmentProfile("GCP", "D", "c3-highcpu-4",
                       "Intel Xeon Platinum 8481C", 3.3, 4, 2, 8, None,
                       124.10),
    EnvironmentProfile("GCP", "E", "c3-highcpu-8",
                       "Intel Xeon Platinum 8481C", 3.3, 8, 2, 16, None,
                       248.21),
    EnvironmentProfile("GCP", "F", "n1-standard-4",
                       "Intel Xeon Platinum 8173M", 3.5, 4, None, 16,
                       "NVIDIA T4", 388.80),
    EnvironmentProfile("GCP", "G", "n1-standard-8",
                       "Intel Xeon Platinum 8173M", 3.5, 8, None, 32,
                       "NVIDIA T4", 525.60),
    # ---- Azure ----
    EnvironmentProfile("Azure", "A", "standard_B4als_v2",
                       "AMD EPYC Milan 7763v", 3.5, 4, 2, 8, None, 95.76),
    EnvironmentProfile("Azure", "B", "standard_B8als_v2",
                       "AMD EPYC Milan 7763v", 3.5, 8, 2, 16, None, 191.52),
    EnvironmentProfile("Azure", "C", "standard_D8lds_v5",
                       "Intel Xeon Platinum 8370C", 3.5, 4, 4, 16, None,
                       276.48),
    EnvironmentProfile("Azure", "D", "standard_F4s_v2",
                       "Intel Xeon Platinum 8370C", 3.7, 4, 2, 8, None,
                       121.68),
    EnvironmentProfile("Azure", "E", "standard_F8s_v2",
                       "Intel Xeon Platinum 8370C", 3.7, 8, 2, 16, None,
                       243.36),
    EnvironmentProfile("Azure", "F", "standard_NC4as_T4_v3",
                       "AMD EPYC Rome 7V12", 3.3, 4, None, 28, "NVIDIA T4",
                       383.98),
    EnvironmentProfile("Azure", "G", "standard_NC8as_T4_v3",
                       "AMD EPYC Rome 7V12", 3.3, 8, None, 56, "NVIDIA T4",
                       548.96),
    # ---- beyond-paper reference point (not part of claim validation) ----
    EnvironmentProfile("TPU", "T", "v5e-1", "TPU v5e (197 TF bf16)",
                       0.94, 8, None, 16, "TPU v5e", 850.0),
)


def profile(provider: str, machine: str) -> EnvironmentProfile:
    for p in PROFILES:
        if p.provider == provider and p.machine == machine:
            return p
    raise KeyError((provider, machine))


def profile_by_key(key: str) -> EnvironmentProfile:
    """Look up by the 'AWS/C' form the CLI and JSONL records use."""
    provider, _, machine = key.partition("/")
    return profile(provider, machine)


def paper_profiles() -> Tuple[EnvironmentProfile, ...]:
    """The 21 scenarios the paper actually ran (no beyond-paper rows)."""
    return tuple(p for p in PROFILES if p.provider in PROVIDERS)
