"""Background hardware telemetry — the deployment lab's Prometheus role.

The paper samples vCPU% and RAM% once per load cell; this module
generalizes ``core.loadtest``'s aggregate ``CpuSampler`` into a ring-buffer
*timeline*: a daemon thread samples per-core CPU utilisation, RAM%, and a
page-fault-rate proxy for cache/memory pressure (no perf counters in the
container, so ``/proc/vmstat`` ``pgfault`` deltas stand in) at a fixed
period, and ``TelemetryTimeline.summary()`` reduces any window of it to the
percentile statistics an ``ExperimentRecord`` carries. ``CpuSampler`` is
kept as the aggregate-only compatibility view that ``core.loadtest``
imports back — the /proc parsing lives only here.

All parsing tolerates a missing /proc (non-Linux hosts): readers return
``None`` and summaries mark the series absent instead of raising.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import List, Optional, Sequence, Tuple


def read_proc_stat() -> Optional[Tuple[int, int]]:
    """Aggregate (total, idle) jiffies from the first /proc/stat line."""
    try:
        with open("/proc/stat") as f:
            parts = f.readline().split()
    except OSError:
        return None
    vals = list(map(int, parts[1:]))
    idle = vals[3] + vals[4]
    return sum(vals), idle


def read_proc_stat_percpu() -> Optional[List[Tuple[int, int]]]:
    """Per-core (total, idle) jiffies from the cpuN lines of /proc/stat."""
    try:
        with open("/proc/stat") as f:
            lines = f.readlines()
    except OSError:
        return None
    out = []
    for line in lines:
        parts = line.split()
        if not parts or not parts[0].startswith("cpu") or parts[0] == "cpu":
            continue
        vals = list(map(int, parts[1:]))
        out.append((sum(vals), vals[3] + vals[4]))
    return out or None


def read_ram_pct() -> Optional[float]:
    try:
        info = {}
        with open("/proc/meminfo") as f:
            for line in f:
                k, v = line.split(":")
                info[k] = int(v.split()[0])
        return 100.0 * (1 - info["MemAvailable"] / info["MemTotal"])
    except (OSError, KeyError, ValueError):
        return None


def read_pgfaults() -> Optional[int]:
    """Cumulative page faults — the cache/memory-pressure proxy counter."""
    try:
        with open("/proc/vmstat") as f:
            for line in f:
                if line.startswith("pgfault "):
                    return int(line.split()[1])
    except (OSError, ValueError):
        pass
    return None


def _util_pct(cur: Tuple[int, int], prev: Tuple[int, int]) -> Optional[float]:
    dt, didle = cur[0] - prev[0], cur[1] - prev[1]
    if dt <= 0:
        return None
    return 100.0 * (1 - didle / dt)


@dataclasses.dataclass(frozen=True)
class TelemetrySample:
    """One telemetry tick (all percentages 0..100). Any series can be None
    when the host can't expose it (e.g. containers whose /proc/stat reports
    frozen jiffies) — a tick is still recorded so the other series keep
    their timeline."""
    t_s: float                         # seconds since sampler start
    cpu_pct: Optional[float]           # aggregate utilisation
    per_core_pct: Tuple[float, ...]    # () when per-core sampling is off
    ram_pct: Optional[float]
    pgfaults_per_s: Optional[float]    # cache/memory-pressure proxy


def _series_summary(vals: Sequence[float]) -> Optional[dict]:
    if not vals:
        return None
    import numpy as np
    arr = np.asarray(vals, float)
    return {"mean": float(arr.mean()),
            "p50": float(np.percentile(arr, 50)),
            "p95": float(np.percentile(arr, 95)),
            "max": float(arr.max())}


@dataclasses.dataclass
class TelemetryTimeline:
    """A (possibly windowed) sequence of samples + its reductions.

    Constructable directly from synthetic samples in tests; the sampler
    produces one via ``timeline()``/``window()``.
    """
    samples: Tuple[TelemetrySample, ...]

    def __len__(self) -> int:
        return len(self.samples)

    @property
    def duration_s(self) -> float:
        if len(self.samples) < 2:
            return 0.0
        return self.samples[-1].t_s - self.samples[0].t_s

    def summary(self) -> dict:
        """Percentile reductions per series — the ExperimentRecord payload.

        ``ram_spread_pct`` (max - min) is the quantity behind the paper's
        RAM-non-interference finding; ``core_imbalance_pct`` (hottest core
        mean minus aggregate mean) exposes single-thread bottlenecks the
        paper's aggregate vCPU% column hides.
        """
        cpu = [s.cpu_pct for s in self.samples if s.cpu_pct is not None]
        ram = [s.ram_pct for s in self.samples if s.ram_pct is not None]
        pgf = [s.pgfaults_per_s for s in self.samples
               if s.pgfaults_per_s is not None]
        out = {"n_samples": len(self.samples),
               "duration_s": self.duration_s,
               "cpu_pct": _series_summary(cpu),
               "ram_pct": _series_summary(ram),
               "pgfaults_per_s": _series_summary(pgf)}
        if ram:
            out["ram_spread_pct"] = float(max(ram) - min(ram))
        cores = [s.per_core_pct for s in self.samples if s.per_core_pct]
        if cores and cpu:
            n = min(len(c) for c in cores)
            per_core_mean = [sum(c[i] for c in cores) / len(cores)
                             for i in range(n)]
            out["core_count"] = n
            out["hottest_core_mean_pct"] = max(per_core_mean)
            out["core_imbalance_pct"] = (max(per_core_mean)
                                         - sum(cpu) / len(cpu))
        return out


class HardwareSampler:
    """Daemon-thread sampler filling a bounded ring buffer of samples.

    Context-manager protocol like the old ``CpuSampler``; additionally a
    ``mark()``/``window()`` pair so one long-lived sampler can attribute
    samples to successive experiment windows (mirroring
    ``ServingEngine.window()`` for engine counters).
    """

    def __init__(self, period_s: float = 0.1, *, maxlen: int = 4096,
                 per_core: bool = True, sample_pgfaults: bool = True):
        self.period = period_s
        self._buf: "collections.deque[TelemetrySample]" = \
            collections.deque(maxlen=maxlen)
        self._stop = threading.Event()
        self._t: Optional[threading.Thread] = None
        self._per_core = per_core
        self._pgfaults = sample_pgfaults
        # window boundary: t_s of the last sample already attributed to a
        # window. Extent-based (not wall-clock) so a sample appended while
        # window()/mark() runs shifts into the next window, never vanishes.
        self._last_t = -1.0
        self.evicted_samples = 0       # ring overwrote this many (total)
        self._t0: Optional[float] = None

    # ------------------------------------------------------------ control
    def __enter__(self) -> "HardwareSampler":
        import time
        self._t0 = time.perf_counter()

        def run():
            prev = read_proc_stat()
            prev_cores = read_proc_stat_percpu() if self._per_core else None
            prev_pgf = read_pgfaults() if self._pgfaults else None
            prev_t = 0.0
            while not self._stop.wait(self.period):
                now = time.perf_counter() - self._t0
                cur = read_proc_stat()
                cpu = (None if cur is None or prev is None
                       else _util_pct(cur, prev))
                prev = cur
                cores: Tuple[float, ...] = ()
                if self._per_core:
                    cur_cores = read_proc_stat_percpu()
                    if cur_cores and prev_cores \
                            and len(cur_cores) == len(prev_cores):
                        cores = tuple(
                            u for u in (_util_pct(c, p) for c, p in
                                        zip(cur_cores, prev_cores))
                            if u is not None)
                    prev_cores = cur_cores
                pgf_rate = None
                if self._pgfaults:
                    cur_pgf = read_pgfaults()
                    if (cur_pgf is not None and prev_pgf is not None
                            and now > prev_t):
                        pgf_rate = (cur_pgf - prev_pgf) / (now - prev_t)
                    prev_pgf = cur_pgf
                if len(self._buf) == self._buf.maxlen:
                    self.evicted_samples += 1
                self._buf.append(TelemetrySample(
                    t_s=now, cpu_pct=cpu, per_core_pct=cores,
                    ram_pct=read_ram_pct(), pgfaults_per_s=pgf_rate))
                prev_t = now

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()
        return self

    def __exit__(self, *exc) -> bool:
        self._stop.set()
        if self._t is not None:
            self._t.join(timeout=2)
        return False

    # ------------------------------------------------------------- access
    def sample_now(self) -> Optional[TelemetrySample]:
        """Take one synchronous sample (no CPU delta — cpu_pct is None) so
        a window shorter than the period still records RAM/host state."""
        import time
        if self._t0 is None:
            return None
        s = TelemetrySample(t_s=time.perf_counter() - self._t0,
                            cpu_pct=None, per_core_pct=(),
                            ram_pct=read_ram_pct(), pgfaults_per_s=None)
        self._buf.append(s)
        return s

    def timeline(self) -> TelemetryTimeline:
        """All buffered samples (oldest may have been evicted by the ring)."""
        return TelemetryTimeline(tuple(self._buf))

    def mark(self) -> None:
        """Start a new attribution window: everything currently buffered
        belongs to the previous window."""
        snap = tuple(self._buf)    # atomic C call, safe vs appender thread
        if snap:
            self._last_t = snap[-1].t_s

    def window(self) -> TelemetryTimeline:
        """Samples since the last ``mark()``/``window()`` (then advances
        the boundary to the snapshot's extent, so a concurrent append only
        shifts a sample into the next window)."""
        snap = tuple(self._buf)
        tl = TelemetryTimeline(tuple(s for s in snap
                                     if s.t_s > self._last_t))
        if snap:
            self._last_t = snap[-1].t_s
        return tl


class CpuSampler(HardwareSampler):
    """Aggregate-CPU% compatibility view (the old ``loadtest.CpuSampler``
    surface: ``.samples`` list of floats + ``.mean``); per-core and
    page-fault sampling off to keep the ladder's per-tick cost identical."""

    def __init__(self, period_s: float = 0.1):
        super().__init__(period_s, per_core=False, sample_pgfaults=False)

    @property
    def samples(self) -> List[float]:
        return [s.cpu_pct for s in self._buf if s.cpu_pct is not None]

    @property
    def mean(self) -> float:
        vals = self.samples
        return float(sum(vals) / len(vals)) if vals else 0.0
