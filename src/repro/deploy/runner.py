"""Experiment grid runner — the paper's protocol (Fig. 7) as a harness.

The paper's method: for each provider x machine environment, fire 2^N
concurrent sentences (N = 0..9) at the deployed service, repeat, record
real-time latency + hardware usage, then derive cost. This module runs
that grid against the live ``serving.Engine``: for every
(profile, scenario) pair it drives ``core.loadtest`` (closed-loop ladder
or open-loop staggered arrivals), attributes hardware telemetry
(``deploy.telemetry`` window) and engine counters (``engine.window()``)
to exactly that run, and emits one structured ``ExperimentRecord`` per
pair as JSONL — the artifact ``deploy.costs`` / ``deploy.report`` price
and diff against the paper.

Honesty note: this container cannot provision AWS/GCP/Azure machines, so
every profile *executes on the local host*; the profile contributes its
spec + hourly price (the record carries both the measured numbers and the
host identity). Cross-profile latency differences therefore reflect run
noise, while cost differences reflect the price book — exactly the
separation the drift report reasons about. On real fleets, point the same
runner at one host per profile.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

from repro.core.loadtest import run_ladder, run_staggered
from repro.deploy.profiles import EnvironmentProfile
from repro.deploy.telemetry import HardwareSampler

# v2: engine sub-dict gained weight_quant / kv_quant / weight_bytes (the
# quantized-serving A/B cells are self-describing)
SCHEMA_VERSION = 2

# every JSONL row carries exactly these top-level fields (tested)
RECORD_FIELDS = ("schema_version", "profile", "scenario", "engine",
                 "cells", "telemetry", "engine_window", "wall_s", "host",
                 "created_unix")

KIND_LADDER = "closed_ladder"
KIND_STAGGERED = "open_staggered"


@dataclasses.dataclass(frozen=True)
class WorkloadScenario:
    """One workload shape on the grid's scenario axis.

    ``closed_ladder``: the paper's burst protocol — NS simultaneous
    sentences per cell, ``repeats`` times. ``open_staggered``: one request
    every ``gap_s`` seconds (decoder engines; the regime continuous
    batching exists for).
    """
    name: str
    kind: str = KIND_LADDER
    mode: str = "encoder"              # engine mode this scenario needs
    ladder: Tuple[int, ...] = (1, 4, 16)
    repeats: int = 2
    n_requests: int = 8                # open_staggered only
    gap_s: float = 0.05
    max_new_tokens: int = 8            # decoder scenarios

    def to_dict(self) -> dict:
        d = {"name": self.name, "kind": self.kind, "mode": self.mode,
             "repeats": self.repeats}
        if self.kind == KIND_LADDER:
            d["ladder"] = list(self.ladder)
        else:
            d.update(n_requests=self.n_requests, gap_s=self.gap_s,
                     max_new_tokens=self.max_new_tokens)
        return d


@dataclasses.dataclass
class ExperimentRecord:
    """One (profile x scenario) measurement — one JSONL row."""
    profile: dict              # EnvironmentProfile.spec_dict()
    scenario: dict             # WorkloadScenario.to_dict()
    engine: dict               # mode / max_batch / continuous / buckets /
    #                            segment_width (see docs/DEPLOY_LAB.md)
    cells: List[dict]          # per-NS ladder cells or one staggered cell
    telemetry: dict            # TelemetryTimeline.summary() of the window
    engine_window: dict        # engine.window() for the run
    wall_s: float
    host: dict
    created_unix: float
    schema_version: int = SCHEMA_VERSION

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)


def host_info() -> dict:
    return {"hostname": platform.node(),   # distinguishes merged grids
            "platform": platform.platform(),
            "cpu_count": os.cpu_count(),
            "note": ("all profiles executed on this host; profile specs "
                     "supply the price book, not the silicon")}


def _ladder_cells(engine, sentences, scenario: WorkloadScenario,
                  rng_seed: int) -> List[dict]:
    cells = run_ladder(engine, sentences, ladder=scenario.ladder,
                       repeats=scenario.repeats, rng_seed=rng_seed,
                       warmup=False)
    return [{"ns": c.ns, "latency_s": c.latency_s,
             "latency_p95_s": c.latency_p95_s, "vcpu_pct": c.vcpu_pct,
             "ram_pct": c.ram_pct, "repeats": c.repeats,
             "sentences_per_s": c.ns / max(c.latency_s, 1e-9)}
            for c in cells]


def _staggered_cells(engine, sentences, scenario: WorkloadScenario,
                     sampling) -> List[dict]:
    prompts = [sentences[i % len(sentences)]
               for i in range(scenario.n_requests)]
    r = run_staggered(engine, prompts, gap_s=scenario.gap_s,
                      sampling=sampling)
    return [{"n_requests": r.n_requests, "gap_s": r.gap_s,
             "latency_p50_s": r.latency_p50_s,
             "latency_p95_s": r.latency_p95_s, "wall_s": r.wall_s,
             "total_tokens": r.total_tokens,
             "tokens_per_s": r.tokens_per_s,
             "requests_per_s": r.n_requests / max(r.wall_s, 1e-9),
             "queue_mean_s": r.queue_mean_s,
             "prefill_mean_s": r.prefill_mean_s,
             "decode_mean_s": r.decode_mean_s,
             "queue_p95_s": r.queue_p95_s}]


class ExperimentRunner:
    """Drives the (profile x scenario) grid against live engines.

    ``engine_factory(scenario)`` returns ``(engine, sentences, sampling)``
    — an engine whose mode matches ``scenario.mode``, the prompt pool, and
    (decoder scenarios) the ``SamplingParams`` for staggered requests. One
    engine is built per *scenario* and shared across the profile axis (the
    jit cache is per engine; profiles differ in price book, not silicon —
    see the module docstring), with ``engine.window()`` attributing
    counters to each profile's run.
    """

    def __init__(self, engine_factory: Callable, *, seed: int = 0,
                 telemetry_period_s: float = 0.05,
                 warmup: bool = True):
        self.engine_factory = engine_factory
        self.seed = seed
        self.telemetry_period_s = telemetry_period_s
        self.warmup = warmup

    def run_grid(self, profiles: Sequence[EnvironmentProfile],
                 scenarios: Sequence[WorkloadScenario],
                 out_path: Optional[str] = None,
                 progress: Optional[Callable[[str], None]] = None
                 ) -> List[ExperimentRecord]:
        records: List[ExperimentRecord] = []
        host = host_info()
        for scenario in scenarios:
            engine, sentences, sampling = self.engine_factory(scenario)
            try:
                if self.warmup:  # pay jit compile outside every window —
                    # every bucket and batch size, not just the first
                    # request's shape (a mixed-bucket scenario would
                    # otherwise compile mid-measurement)
                    engine.warmup()
                with HardwareSampler(self.telemetry_period_s) as hw:
                    for i, prof in enumerate(profiles):
                        if progress:
                            progress(f"{prof.key} x {scenario.name}")
                        engine.window()      # reset engine counters
                        hw.mark()            # reset telemetry window
                        t0 = time.perf_counter()
                        if scenario.kind == KIND_LADDER:
                            cells = _ladder_cells(engine, sentences,
                                                  scenario, self.seed + i)
                        elif scenario.kind == KIND_STAGGERED:
                            cells = _staggered_cells(engine, sentences,
                                                     scenario, sampling)
                        else:
                            raise ValueError(
                                f"unknown scenario kind {scenario.kind!r}")
                        wall = time.perf_counter() - t0
                        hw.sample_now()   # >=1 sample even for sub-period runs
                        tel = hw.window().summary()
                        if hw.evicted_samples:
                            # the ring overwrote samples at some point this
                            # grid: percentiles may cover only a tail
                            tel["evicted_samples_total"] = \
                                hw.evicted_samples
                        records.append(ExperimentRecord(
                            profile=prof.spec_dict(),
                            scenario=scenario.to_dict(),
                            engine=_engine_summary(engine),
                            cells=cells,
                            telemetry=tel,
                            engine_window=engine.window(),
                            wall_s=wall, host=host,
                            created_unix=time.time()))
            finally:
                engine.close()
        if out_path is not None:
            write_jsonl(records, out_path)
        return records


def _engine_summary(engine) -> dict:
    ec = engine.ec
    return {"mode": ec.mode, "max_batch": ec.max_batch,
            "pad_buckets": list(ec.pad_buckets),
            "continuous": bool(engine.continuous_active),
            "max_new_tokens": ec.max_new_tokens,
            "segment_width": ec.segment_width,
            "prefix_cache": bool(ec.prefix_cache),
            # weight/KV dtypes (None = bf16/f32 default path) + resident
            # weight bytes, so quant A/B grid cells are self-describing
            "weight_quant": ec.weight_quant,
            "kv_quant": ec.kv_quant,
            "weight_bytes": int(getattr(engine, "_weight_bytes", 0)),
            # draft-and-verify knobs, so spec A/B grid cells are
            # self-describing too
            "spec_decode": bool(ec.spec_decode),
            "spec_k": ec.spec_k}


def write_jsonl(records: Iterable[ExperimentRecord], path: str) -> None:
    with open(path, "w") as f:
        for rec in records:
            f.write(rec.to_json() + "\n")


def read_jsonl(path: str) -> List[dict]:
    """Record dicts back from a JSONL artifact (costs/report input)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def records_as_dicts(records: Sequence) -> List[dict]:
    """Uniform dict view whether given ExperimentRecords or JSONL dicts."""
    return [r.to_dict() if isinstance(r, ExperimentRecord) else r
            for r in records]


def smoke_grid_profiles() -> Tuple[EnvironmentProfile, ...]:
    """The CI smoke pair: one CPU profile (the paper's capacity hero,
    AWS/C) and one GPU profile (AWS/G) so the cost report exercises both
    sides of the GPU-premium diff."""
    from repro.deploy.profiles import profile
    return (profile("AWS", "C"), profile("AWS", "G"))
