"""Live cost accounting — the paper's Table-5 economics recomputed from
*measured* throughput instead of published latency tables.

``core.costmodel`` prices the paper's own numbers; this module prices
``ExperimentRecord`` data produced by ``deploy.runner``: US$ per million
sentences at each profile's measured best SLO-compliant operating point,
the cheapest machine that still meets the SLO at a target concurrency, and
the GPU-vs-CPU break-even (how much faster the GPU machine must measure
before its price premium inverts per-sentence). ``deploy.report`` diffs
each of these against the paper-side values.

All functions take plain record dicts (the JSONL rows), not runner
objects, so a report can be rebuilt from committed artifacts alone.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.deploy.profiles import (LATENCY_SLO_S, EnvironmentProfile,
                                   profile_by_key)


def record_key(rec: dict) -> str:
    """The one definition of a record's 'PROVIDER/MACHINE' key (matches
    ``EnvironmentProfile.key`` and ``profile_by_key``)."""
    return rec["profile"]["provider"] + "/" + rec["profile"]["machine"]


def usd_per_million_sentences(sentences_per_s: float,
                              hourly_usd: float) -> float:
    """$/1M sentences from a measured rate at a profile's hourly price."""
    if sentences_per_s <= 0:
        return float("inf")
    return hourly_usd / 3600.0 / sentences_per_s * 1e6


def best_slo_point(cells: List[dict],
                   slo_s: float = LATENCY_SLO_S) -> Optional[dict]:
    """The highest-throughput ladder cell whose mean latency meets the SLO
    (the paper's 'best operating point'); None when every cell misses."""
    ok = [c for c in cells if c["latency_s"] <= slo_s]
    if not ok:
        return None
    return max(ok, key=lambda c: c["sentences_per_s"])


def measured_cost_table(records: List[dict],
                        slo_s: float = LATENCY_SLO_S) -> Dict[str, dict]:
    """Per profile key: measured $/1M sentences at the best SLO point.

    ``inf`` (None best point) means the profile never met the SLO in the
    grid — the paper's 'unviable deployment' verdict, priced accordingly.
    """
    out: Dict[str, dict] = {}
    for rec in records:
        if rec["scenario"]["kind"] != "closed_ladder":
            continue
        key = record_key(rec)
        best = best_slo_point(rec["cells"], slo_s)
        rate = best["sentences_per_s"] if best else 0.0
        usd = usd_per_million_sentences(
            rate, rec["profile"]["hourly_cost_usd"])
        prev = out.get(key)
        if prev is None or usd < prev["usd_per_1m_sentences"]:
            out[key] = {"usd_per_1m_sentences": usd,
                        "best_ns": best["ns"] if best else None,
                        "sentences_per_s": rate,
                        "hourly_cost_usd":
                            rec["profile"]["hourly_cost_usd"]}
    return out


def measured_max_ns_within_slo(cells: List[dict],
                               slo_s: float = LATENCY_SLO_S) -> int:
    """Largest ladder NS whose measured mean latency meets the SLO."""
    return max((c["ns"] for c in cells if c["latency_s"] <= slo_s),
               default=0)


def cheapest_slo_compliant(records: List[dict], *, target_ns: int = 1,
                           slo_s: float = LATENCY_SLO_S) -> Optional[str]:
    """Cheapest (hourly) profile in the grid that meets the SLO at
    >= target_ns concurrent sentences — the paper's POC feasibility
    question, answered from measurements."""
    feasible = []
    for rec in records:
        if rec["scenario"]["kind"] != "closed_ladder":
            continue
        if measured_max_ns_within_slo(rec["cells"], slo_s) >= target_ns:
            feasible.append((rec["profile"]["hourly_cost_usd"],
                             record_key(rec)))
    return min(feasible)[1] if feasible else None


def gpu_vs_cpu_premium(records: List[dict]) -> dict:
    """GPU-vs-CPU economics over the grid's profiles.

    * ``price_ratio``: mean GPU hourly price over mean CPU hourly price
      (the paper's '300% more expensive' axis — pure price book).
    * ``cost_per_sentence_ratio``: same ratio after dividing by measured
      throughput (the utilization-corrected number the paper couldn't
      compute); None unless the grid measured both kinds.
    * ``breakeven_speedup``: how much faster the GPU profiles must process
      sentences for their per-sentence cost to match the CPU profiles —
      exactly ``price_ratio`` by construction, reported for the drift
      report's narrative.
    """
    table = measured_cost_table(records)
    cpu, gpu = {}, {}
    for key, row in table.items():
        (gpu if profile_by_key(key).is_gpu else cpu)[key] = row

    def _mean(rows, field):
        vals = [r[field] for r in rows.values() if r[field] != float("inf")]
        return sum(vals) / len(vals) if vals else None

    price_cpu = _mean(cpu, "hourly_cost_usd")
    price_gpu = _mean(gpu, "hourly_cost_usd")
    cps_cpu = _mean(cpu, "usd_per_1m_sentences")
    cps_gpu = _mean(gpu, "usd_per_1m_sentences")
    price_ratio = (price_gpu / price_cpu
                   if price_cpu and price_gpu else None)
    return {"price_ratio": price_ratio,
            "cost_per_sentence_ratio": (cps_gpu / cps_cpu
                                        if cps_cpu and cps_gpu else None),
            "breakeven_speedup": price_ratio,
            "n_cpu_profiles": len(cpu), "n_gpu_profiles": len(gpu)}


def profile_price_ratio(profiles: List[EnvironmentProfile]) -> Optional[float]:
    """Mean-GPU / mean-CPU hourly price over a profile set (price book
    only — no measurements needed)."""
    cpu = [p.hourly_cost_usd for p in profiles if not p.is_gpu]
    gpu = [p.hourly_cost_usd for p in profiles if p.is_gpu]
    if not cpu or not gpu:
        return None
    return (sum(gpu) / len(gpu)) / (sum(cpu) / len(cpu))
