"""Deployment lab — the live counterpart of the paper's experiment protocol.

The paper's contribution is a *protocol*, not a table: 7 machine classes
across 3 providers, repeated load experiments, real-time latency + hardware
usage + cost. ``repro.core`` replays the paper's published numbers;
this package re-runs the protocol against the serving engine built in this
repo:

  * ``profiles``  — executable environment profiles (provider x machine
    specs + the price book), the single source of truth that
    ``core.environments`` / ``core.costmodel`` re-export from;
  * ``telemetry`` — background hardware sampler (per-core CPU, RAM,
    page-fault proxy) with ring-buffer timelines and percentile summaries;
  * ``runner``    — the profile x scenario experiment grid, emitting
    structured ``ExperimentRecord`` JSONL;
  * ``costs``     — live cost accounting from *measured* throughput
    ($ / 1M sentences, GPU-vs-CPU break-even, cheapest-SLO selection);
  * ``report``    — the drift report: paper findings recomputed from
    measured data and diffed against ``core.analysis`` expectations.

Import layering: ``profiles`` and ``telemetry`` are leaf modules (``core``
imports *them*); ``runner``/``costs``/``report`` sit above ``core`` and
``serving`` and are therefore loaded lazily here to keep
``core.environments -> deploy.profiles`` cycle-free.
"""
from repro.deploy.profiles import (HOURS_PER_MONTH,  # noqa: F401
                                   LATENCY_SLO_S, MACHINES, NS_LADDER,
                                   PROFILES, PROVIDERS, EnvironmentProfile,
                                   paper_profiles, profile, profile_by_key)
from repro.deploy.telemetry import (CpuSampler, HardwareSampler,  # noqa: F401
                                    TelemetrySample, TelemetryTimeline)

_LAZY = {
    "ExperimentRecord": "repro.deploy.runner",
    "ExperimentRunner": "repro.deploy.runner",
    "WorkloadScenario": "repro.deploy.runner",
    "drift_report": "repro.deploy.report",
    "format_drift": "repro.deploy.report",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(name)
    import importlib
    return getattr(importlib.import_module(mod), name)
