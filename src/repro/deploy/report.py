"""Drift report — the paper's findings recomputed from measured data and
diffed against the ``core.analysis`` expectations.

For every headline finding the paper states (and ``core.analysis``
validates against the paper's own tables), this module computes the
measured counterpart from an experiment grid's ``ExperimentRecord``s where
the grid can observe it, and marks it ``unobservable`` (with the reason)
where it cannot — e.g. cross-profile latency contrasts are meaningless
when every profile executed on the same host. The three quantities the
acceptance gate names — measured $/1M sentences, cheapest-SLO-compliant
machine, GPU-vs-CPU premium — are always diffed numerically.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.deploy import costs
from repro.deploy.profiles import LATENCY_SLO_S, profile_by_key
from repro.deploy.runner import records_as_dicts

# the paper's five headline findings (core.analysis validates each against
# the paper's own tables; the report must list every one)
PAPER_FINDINGS = ("gpu_latency_dominance", "gpu_cost_premium",
                  "cache_dominance", "ram_non_interference",
                  "low_power_cpu_threshold")


def _paper_cost_per_million() -> Dict[str, float]:
    from repro.core import costmodel
    cpm = costmodel.cost_per_million_sentences()
    return {f"{prov}/{m}": v for prov, row in cpm.items()
            for m, v in row.items()}


def _single_host(records: List[dict]) -> bool:
    return len({json.dumps(r["host"], sort_keys=True)
                for r in records}) <= 1


def _measured_findings(records: List[dict], single_host: bool) -> dict:
    """Measured counterpart (or unobservability verdict) per finding."""
    ladder = [r for r in records
              if r["scenario"]["kind"] == "closed_ladder"]
    cross_profile = ("requires per-profile hardware; this grid ran every "
                     "profile on one host" if single_host else None)
    out: Dict[str, dict] = {}

    # GPU latency dominance + cache dominance need real silicon contrasts.
    for name in ("gpu_latency_dominance", "cache_dominance"):
        out[name] = ({"status": "unobservable", "reason": cross_profile}
                     if cross_profile else {"status": "not_computed",
                                            "reason": "multi-host grid "
                                            "analysis not implemented"})

    # GPU cost premium: the price-book side is exact; the measured
    # cost-per-sentence side works even single-host.
    prem = costs.gpu_vs_cpu_premium(records_as_dicts(ladder))
    out["gpu_cost_premium"] = {"status": "measured", **prem}

    # RAM non-interference: telemetry RAM spread over each run's window.
    spreads = [r["telemetry"].get("ram_spread_pct") for r in ladder]
    spreads = [s for s in spreads if s is not None]
    if spreads:
        out["ram_non_interference"] = {
            "status": "measured", "max_ram_spread_pct": max(spreads),
            "holds": max(spreads) <= 10.0}
    else:
        out["ram_non_interference"] = {
            "status": "unobservable", "reason": "no RAM telemetry samples"}

    # Low-power CPU threshold: vCPU% at the first SLO-crossing ladder cell.
    crossings = {}
    for r in ladder:
        key = costs.record_key(r)
        for c in r["cells"]:
            if c["latency_s"] > LATENCY_SLO_S:
                crossings[key] = {"ns": c["ns"], "vcpu_pct": c["vcpu_pct"]}
                break
    out["low_power_cpu_threshold"] = (
        {"status": "measured", "crossings": crossings} if crossings
        else {"status": "unobservable",
              "reason": "no ladder cell crossed the SLO in this grid"})
    return out


def drift_report(records, *, target_ns: Optional[int] = None) -> dict:
    """Diff a grid's measurements against the paper-side expectations.

    ``target_ns`` for the cheapest-SLO-compliant question defaults to the
    largest ladder NS the grid actually ran (the paper uses 32; a smoke
    grid tops out lower and must not be judged against cells it never
    fired).
    """
    from repro.core import analysis, costmodel
    records = records_as_dicts(list(records))
    ladder = [r for r in records
              if r["scenario"]["kind"] == "closed_ladder"]
    if target_ns is None:
        target_ns = max((c["ns"] for r in ladder for c in r["cells"]),
                        default=1)
    single_host = _single_host(records)

    # --- measured $/1M sentences vs the paper's table -------------------
    paper_cpm = _paper_cost_per_million()
    measured_cpm = costs.measured_cost_table(ladder)
    cpm_diff = {}
    for key, row in measured_cpm.items():
        paper = paper_cpm.get(key)
        measured = row["usd_per_1m_sentences"]
        cpm_diff[key] = {
            "measured_usd_per_1m": measured,
            "paper_usd_per_1m": paper,
            "measured_best_ns": row["best_ns"],
            "ratio_measured_over_paper": (
                measured / paper
                if paper not in (None, 0.0) and measured != float("inf")
                else None)}

    # --- cheapest SLO-compliant machine ---------------------------------
    measured_cheapest = costs.cheapest_slo_compliant(ladder,
                                                     target_ns=target_ns)
    # the apples-to-apples paper answer: cheapest among the profiles this
    # grid actually ran, judged by the paper's own Tables 2-4 latencies
    grid_keys = sorted({costs.record_key(r) for r in ladder})
    paper_feasible = []
    for key in grid_keys:
        p = profile_by_key(key)
        if p.provider not in costmodel.PROVIDERS:
            continue              # beyond-paper rows have no Tables 2-4
        if costmodel.max_ns_within_slo(p.provider, p.machine) >= target_ns:
            paper_feasible.append((p.hourly_cost_usd, key))
    paper_in_grid = min(paper_feasible)[1] if paper_feasible else None
    cheapest = {
        "target_ns": target_ns,
        "measured": measured_cheapest,
        "paper_among_grid_profiles": paper_in_grid,
        "paper_all_machines": {
            prov: m for prov, m in
            costmodel.cheapest_slo_compliant(target_ns=target_ns).items()},
        "agrees_with_paper": (measured_cheapest == paper_in_grid
                              if measured_cheapest and paper_in_grid
                              else None)}

    # --- GPU-vs-CPU premium ---------------------------------------------
    paper_prem = costmodel.gpu_cost_premium()
    grid_profiles = [profile_by_key(k) for k in
                     {costs.record_key(r) for r in records}]
    measured_prem = costs.gpu_vs_cpu_premium(ladder)
    premium = {
        "paper_claim_pct": 300,
        "paper_table5_ratio_overall": paper_prem["overall"],
        "grid_price_ratio": costs.profile_price_ratio(grid_profiles),
        "measured": measured_prem}

    # --- findings ledger -------------------------------------------------
    paper_findings = analysis.all_findings()
    measured_findings = _measured_findings(records, single_host)
    findings = {name: {"paper_holds": bool(paper_findings[name]["holds"]),
                       "measured": measured_findings[name]}
                for name in PAPER_FINDINGS}

    return {"schema_version": 1,
            "n_records": len(records),
            "profiles": sorted({costs.record_key(r)
                                for r in records}),
            "scenarios": sorted({r["scenario"]["name"] for r in records}),
            "single_host_grid": single_host,
            "cost_per_million_sentences": cpm_diff,
            "cheapest_slo_compliant": cheapest,
            "gpu_vs_cpu_premium": premium,
            "findings": findings}


def format_drift(report: dict) -> str:
    """Human-readable rendering of ``drift_report()`` output."""
    L = ["== deployment-lab drift report ==",
         f"records: {report['n_records']}  "
         f"profiles: {', '.join(report['profiles'])}  "
         f"scenarios: {', '.join(report['scenarios'])}"]
    if report["single_host_grid"]:
        L.append("(single-host grid: profile prices are real, profile "
                 "silicon is this host)")
    L.append("-- $/1M sentences (measured vs paper) --")
    for key, d in sorted(report["cost_per_million_sentences"].items()):
        m, p = d["measured_usd_per_1m"], d["paper_usd_per_1m"]
        ratio = d["ratio_measured_over_paper"]
        L.append(f"  {key:10s} measured={m:10.2f}  "
                 f"paper={p if p is not None else float('nan'):10.2f}  "
                 f"x{ratio:.2f}" if ratio is not None else
                 f"  {key:10s} measured={m}  paper={p}")
    ch = report["cheapest_slo_compliant"]
    L.append(f"-- cheapest SLO-compliant @ NS>={ch['target_ns']} --")
    L.append(f"  measured: {ch['measured']}  paper (same profiles): "
             f"{ch['paper_among_grid_profiles']}  agree: "
             f"{ch['agrees_with_paper']}")
    pr = report["gpu_vs_cpu_premium"]
    L.append("-- GPU vs CPU premium --")
    L.append(f"  paper claim: {pr['paper_claim_pct']}%  table5 ratio: "
             f"{pr['paper_table5_ratio_overall']:.2f}x  grid price "
             f"ratio: {pr['grid_price_ratio']:.2f}x"
             if pr["grid_price_ratio"] is not None else
             f"  paper claim: {pr['paper_claim_pct']}% (grid has no "
             f"GPU/CPU pair)")
    meas = pr["measured"]["cost_per_sentence_ratio"]
    if meas is not None:
        L.append(f"  measured $/sentence ratio: {meas:.2f}x  "
                 f"(breakeven speedup: "
                 f"{pr['measured']['breakeven_speedup']:.2f}x)")
    L.append("-- findings ledger --")
    for name, d in report["findings"].items():
        m = d["measured"]
        extra = (f"measured_holds={m['holds']}" if "holds" in m
                 else m["status"])
        L.append(f"  {name:26s} paper_holds={d['paper_holds']}  {extra}")
    return "\n".join(L)


def write_report(report: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
