"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1, head_dim 256) d_ff=12288 vocab=256000.
RG-LRU + local attention at 1:2 attention:recurrent ratio; 38 = 2 periods of
a 19-block pattern (six (rec,rec,attn) triples + one trailing rec). Local
window 2048. Recurrent state decode -> long_500k admissible.
"""
from repro.models.config import AttnConfig, ModelConfig

_PATTERN = ("rglru", "rglru", "attn_local") * 6 + ("rglru",)

CONFIG = ModelConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12_288, vocab_size=256_000, head_dim=256,
    pattern=_PATTERN,
    act="gelu", tie_embeddings=True,
    attn=AttnConfig(window=2048, rope_base=10_000.0),
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", arch_type="hybrid",
    n_layers=3, d_model=256, n_heads=4, n_kv_heads=1,
    d_ff=512, vocab_size=512, head_dim=64,
    pattern=("rglru", "rglru", "attn_local"),
    act="gelu", tie_embeddings=True,
    attn=AttnConfig(window=64, rope_base=10_000.0),
)
