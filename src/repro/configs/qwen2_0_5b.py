"""Qwen2-0.5B [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias, tied
embeddings. 14 heads do not divide the 16-way model axis -> attention is
replicated; MLP and vocab remain model-sharded (see DESIGN.md).
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", arch_type="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151_936,
    tie_embeddings=True,
    attn=AttnConfig(qkv_bias=True, rope_base=1e6),
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", arch_type="dense",
    n_layers=2, d_model=224, n_heads=14, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    tie_embeddings=True,
    attn=AttnConfig(qkv_bias=True, rope_base=1e6),
)
