"""Architecture config registry.

Each module defines ``CONFIG`` (the exact assigned spec) and ``SMOKE`` (a
reduced same-family variant: <=2-ish layers / one pattern period, d_model
<= 512, <= 4 experts) used by the CPU smoke tests.
"""
from __future__ import annotations

import importlib

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "xlstm-125m": "xlstm_125m",
    "stablelm-12b": "stablelm_12b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a6_6b",
    "qwen2-0.5b": "qwen2_0_5b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "gemma2-27b": "gemma2_27b",
    "whisper-large-v3": "whisper_large_v3",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gector-base": "gector_base",
}

ARCHS = [a for a in _MODULES if a != "gector-base"]


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG
