"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) vocab=151936; 60 routed experts top-4 with
expert d_ff=1408 + 4 shared experts (4x1408 = 5632 fused shared width,
matching the model card). 60 experts do not divide the 16-way model axis ->
tensor-parallel expert sharding is auto-selected (see parallel.sharding).
"""
from repro.models.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch_type="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151_936,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                  expert_d_ff=1408),
    attn=AttnConfig(qkv_bias=True, rope_base=1e6),
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", arch_type="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=2,
                  expert_d_ff=512),
    attn=AttnConfig(qkv_bias=True, rope_base=1e6),
)
