"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (kv=16) vocab=163840; MoE 64 experts top-6 with expert
d_ff=1408 + 2 shared experts (DeepSeek-V3-style). 64 experts divide the
16-way axis -> expert-parallel sharding (4 experts/shard).
"""
from repro.models.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", arch_type="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163_840,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  expert_d_ff=1408),
    attn=AttnConfig(rope_base=50_000.0),
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", arch_type="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                  expert_d_ff=512),
    attn=AttnConfig(rope_base=50_000.0),
)
