"""Phi-3.5-MoE-instruct [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) vocab=32064; 16 experts top-2, expert
d_ff=6400, no shared experts. Expert-parallel: exactly one expert per
model shard on the 16-way axis.
"""
from repro.models.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32_064,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=6400),
    norm="layernorm",
    attn=AttnConfig(rope_base=10_000.0),
)

SMOKE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b-smoke", arch_type="moe",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=512),
    norm="layernorm",
    attn=AttnConfig(rope_base=10_000.0),
)
