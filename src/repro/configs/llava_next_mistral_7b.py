"""LLaVA-NeXT (v1.6) Mistral-7B backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. The SigLIP/CLIP
vision tower + projector are a STUB per the assignment: input_specs supplies
pre-computed anyres patch embeddings (vis_tokens=2880 = 5 tiles x 576) that
are interleaved (prefixed) before the text tokens.
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch_type="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14_336, vocab_size=32_000,
    vis_tokens=2880,
    attn=AttnConfig(rope_base=1e6),
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke", arch_type="vlm",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    vis_tokens=16,
    attn=AttnConfig(rope_base=1e6),
)
