"""StableLM-2-12B family [hf:stabilityai/stablelm-2-1_6b scaled per
assignment]. 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
kv=8 < 16-way model axis -> KV projections replicated (see DESIGN.md)."""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", arch_type="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13_824, vocab_size=100_352,
    norm="layernorm",
    attn=AttnConfig(rope_base=10_000.0),
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke", arch_type="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    norm="layernorm",
    attn=AttnConfig(rope_base=10_000.0),
)
