"""Gemma-2-27B [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128,
alternating local(window 4096)/global attention, attn logit softcap 50,
final logit softcap 30, post-norms, GeGLU, tied embeddings.

long_500k runs under the documented *windowed-global* variant: global layers
cap their effective window at 32768 during long-context decode
(attn.long_ctx_window_cap) — the sliding-window carve-out of the shape rules.
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", arch_type="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36_864, vocab_size=256_000, head_dim=128,
    pattern=("attn_local", "attn_global"),
    act="gelu", post_norms=True, tie_embeddings=True,
    final_logit_softcap=30.0,
    attn=AttnConfig(window=4096, logit_softcap=50.0, rope_base=10_000.0,
                    long_ctx_window_cap=32_768),
)

SMOKE = ModelConfig(
    name="gemma2-27b-smoke", arch_type="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=4,
    d_ff=512, vocab_size=512, head_dim=32,
    pattern=("attn_local", "attn_global"),
    act="gelu", post_norms=True, tie_embeddings=True,
    final_logit_softcap=30.0,
    attn=AttnConfig(window=64, logit_softcap=50.0, rope_base=10_000.0,
                    long_ctx_window_cap=128),
)
