"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder.

Decoder (the assigned backbone): 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 (padded to 51968 for even vocab-parallel sharding). Encoder: 32
layers over 1500 stub frame embeddings — the mel-spectrogram + conv frontend
is a STUB per the assignment (input_specs supplies (B, 1500, 1280)).
Learned absolute positions, LayerNorm, GELU, non-gated MLP. 20 heads do not
divide the 16-way axis -> attention replicated, MLP/vocab sharded.
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", arch_type="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51_866,
    enc_layers=32, enc_seq_len=1500,
    norm="layernorm", act="gelu", gated_mlp=False, abs_pos=True,
    attn=AttnConfig(rope_base=None),
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke", arch_type="audio",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512,
    enc_layers=2, enc_seq_len=64,
    norm="layernorm", act="gelu", gated_mlp=False, abs_pos=True,
    attn=AttnConfig(rope_base=None),
)
