"""xLSTM-125M [arXiv:2405.04517].

12L d_model=768 4H, vocab=50304, d_ff=0 (blocks carry their own projections).
5:1 mLSTM:sLSTM ratio -> pattern of five mLSTM + one sLSTM, two periods.
Attention-free (recurrent state decode) -> long_500k admissible.
"""
from repro.models.config import AttnConfig, ModelConfig

_PATTERN = ("mlstm",) * 5 + ("slstm",)

CONFIG = ModelConfig(
    name="xlstm-125m", arch_type="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    pattern=_PATTERN,
    attn=AttnConfig(rope_base=None),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-125m-smoke", arch_type="ssm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512,
    pattern=("mlstm", "slstm"),
    attn=AttnConfig(rope_base=None),
    tie_embeddings=True,
)
