"""GECToR (Omelianchuk et al., 2020) — the paper's model.

BERT-base bidirectional encoder (12L d_model=768 12H d_ff=3072, learned
absolute positions, LayerNorm, GELU, non-gated MLP) with two linear heads
(error-detection + edit-tag labels) stacked on top — see core/gector.py.

SMOKE is the variant trained/served in the examples and load tests on CPU.
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gector-base", arch_type="encoder",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=30_522,
    norm="layernorm", act="gelu", gated_mlp=False, abs_pos=True,
    attn=AttnConfig(rope_base=None),
    max_seq_len=512,
)

SMOKE = ModelConfig(
    name="gector-small", arch_type="encoder",
    n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=1024, vocab_size=8192,
    norm="layernorm", act="gelu", gated_mlp=False, abs_pos=True,
    attn=AttnConfig(rope_base=None),
    max_seq_len=128,
)
