"""Training launcher: builds the mesh (production or host), derives sharding
rules, initializes sharded state, and runs the training loop.

On this CPU host it runs reduced configs end-to-end; pointed at a TPU
slice it builds the 16x16 (or 2x16x16) mesh from the same code path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.parallel.sharding import rules_for, use_rules
from repro.training.checkpoint import save
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import OptConfig, adamw_init
from repro.training.train_loop import train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if jax.device_count() >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh()
    rules = rules_for(cfg, mesh, multi_pod=args.multi_pod)
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                   total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, batch_size=args.batch))

    with use_rules(rules), mesh:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(lambda p, o, b: train_step(cfg, oc, p, o, b,
                                                  remat=True))
        t0 = time.time()
        for i, b in enumerate(data.batches(args.steps)):
            batch = {"tokens": jnp.asarray(b["tokens"])}
            if cfg.enc_layers:
                batch["enc_embeds"] = jnp.zeros(
                    (args.batch, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
            if cfg.vis_tokens:
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.vis_tokens, cfg.d_model), jnp.bfloat16)
            params, opt, m = step(params, opt, batch)
            if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f} "
                      f"[{time.time()-t0:.0f}s]")
    if args.ckpt:
        save(args.ckpt, {"params": params, "opt": opt})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
