import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and extract the roofline
terms. This is the proof that the distribution config is coherent without
real hardware. MUST be run as its own process (the XLA_FLAGS line above has
to execute before any jax import anywhere).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
  ... add --multi-pod for the 2x16x16 = 512-chip mesh.
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402

from repro.configs import ARCHS, get_config                 # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.specs import build_step                   # noqa: E402
from repro.models.config import SHAPES                      # noqa: E402
from repro.parallel.sharding import rules_for, use_rules    # noqa: E402

# ---- TPU v5e hardware model (per chip) ----
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DT_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
             "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
             "u16": 2, "c64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Sum tensor sizes flowing through collectives in the (post-SPMD,
    per-device) optimized HLO. Methodology: the *result* shape of each
    collective op is counted once — a per-device upper bound consistent
    across configs (operands of all-reduce equal its result; all-gather
    results count the gathered size each device materializes)."""
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] += n * _DT_BYTES.get(dt, 4)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def model_flops(cfg, shape) -> float:
    """6*N_active*D for train (fwd+bwd), 2*N_active*D per generated/scored
    token otherwise."""
    n_act = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch      # one token per request


def admissible(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return False
    return True


def _compile_once(cfg, shape, rules, mesh, kw):
    fn, args, shardings = build_step(cfg, shape, rules, **kw)
    # donate the state pytrees (params+opt for train, the KV/recurrent cache
    # for serving) — the production configuration; without it XLA double-
    # buffers multi-GiB state (temp 19.4 -> ~6 GiB on gemma2-27b train_4k)
    donate = (0, 1) if shape.mode == "train" else (1,)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        return lowered.compile()


def measure_costs(cfg, shape, rules, mesh, kw) -> dict:
    """Per-device FLOPs/bytes/collective-bytes, corrected for XLA's
    count-while-bodies-once behaviour by depth differencing: compile 1- and
    2-period variants in COST_MODE (inner loops collapsed) and extrapolate
    linearly to the full period count. See runtime_flags.COST_MODE."""
    from repro.models import runtime_flags
    plen = len(cfg.pattern)
    meas = []
    runtime_flags.set_cost_mode(True)
    try:
        for mult in (1, 2):
            repl = {"n_layers": plen * mult}
            if cfg.enc_layers:
                repl["enc_layers"] = mult
            cfg_s = dataclasses.replace(cfg, **repl)
            compiled = _compile_once(cfg_s, shape, rules, mesh, kw)
            cost = compiled.cost_analysis()
            coll = collective_bytes(compiled.as_text())
            meas.append({
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(coll["total_bytes"]),
                "coll_detail": coll,
            })
    finally:
        runtime_flags.set_cost_mode(False)
    # NOTE: for enc-dec (whisper) enc_layers == n_layers, so the same P-1
    # multiplier extrapolates encoder and decoder stacks together.
    P = cfg.n_periods
    out = {}
    for key in ("flops", "bytes", "coll"):
        d = meas[1][key] - meas[0][key]
        out[key] = meas[0][key] + max(d, 0.0) * (P - 1)
    out["per_period"] = {k: meas[1][k] - meas[0][k]
                         for k in ("flops", "bytes", "coll")}
    out["base"] = meas[0]
    out["coll_detail_period"] = meas[1]["coll_detail"]
    return out


def run_one(arch: str, shape_name: str, *, multi_pod=False, seq_shard=None,
            verbose=True, with_costs=True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not admissible(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped":
                "full-attention arch at 500k decode (see DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, multi_pod=multi_pod)
    if seq_shard is None:
        seq_shard = shape.mode == "train"   # optimized default (see §Perf)
    kw = {"seq_shard": seq_shard} if shape.mode == "train" else {}

    t0 = time.time()
    with use_rules(rules):
        compiled = _compile_once(cfg, shape, rules, mesh, kw)
        t1 = time.time()
        if with_costs:
            costs = measure_costs(cfg, shape, rules, mesh, kw)
        else:   # multi-pod pass: lower+compile proof only (roofline is
            costs = {"flops": 0.0, "bytes": 0.0, "coll": 0.0,  # single-pod)
                     "per_period": {}, "coll_detail_period": {}}

    mem = compiled.memory_analysis()
    coll = {"total_bytes": costs["coll"],
            "detail": costs["coll_detail_period"]}
    n_chips = mesh.size

    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": float(coll["total_bytes"]) / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_s": round(t1 - t0, 1),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": float(coll["total_bytes"]),
            "collective_detail": coll["detail"],
            "per_period": costs["per_period"],
            "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
            # XLA's liveness-based peak + resident state (args). The CPU
            # backend ignores donation and reports temp without reuse, so
            # temp_bytes overstates; this is the HBM-fit criterion.
            "peak_bytes": float(
                getattr(mem, "peak_memory_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)),
            # donation-adjusted: on TPU the donated state (params+opt for
            # train, the KV cache for serving) aliases its output, so the
            # output copy the CPU backend counts does not exist there
            "adjusted_peak_bytes": float(
                getattr(mem, "peak_memory_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                - min(getattr(mem, "output_size_in_bytes", 0),
                      getattr(mem, "argument_size_in_bytes", 0))),
        },
        "roofline": {**{k: terms[k] for k in terms},
                     "dominant": dominant,
                     "model_flops_total": mf,
                     "useful_flops_ratio":
                         mf / max(flops_dev * n_chips, 1.0)},
        "seq_shard": seq_shard,
    }
    if verbose:
        pd = rec["per_device"]
        print(f"[{arch} x {shape_name} @ {rec['mesh']}] "
              f"compile {rec['compile_s']}s | "
              f"flops/dev {pd['hlo_flops']:.3e} | "
              f"bytes/dev {pd['hlo_bytes']:.3e} | "
              f"coll/dev {pd['collective_bytes']:.3e} | "
              f"peak/dev {pd['peak_bytes']/2**30:.2f} GiB | "
              f"dominant={dominant}")
        print(f"  roofline: compute {terms['compute_s']*1e3:.2f} ms, "
              f"memory {terms['memory_s']*1e3:.2f} ms, "
              f"collective {terms['collective_s']*1e3:.2f} ms | "
              f"useful-flops ratio "
              f"{rec['roofline']['useful_flops_ratio']:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-shard", type=int, default=None,
                    help="override train seq sharding (0/1)")
    ap.add_argument("--no-costs", action="store_true",
                    help="skip the cost-measurement compiles (compile-proof "
                         "only; used for the multi-pod pass)")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ARCHS for s in
                 ("train_4k", "prefill_32k", "decode_32k", "long_500k")]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        pairs = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    seq_shard = None if args.seq_shard is None else bool(args.seq_shard)
    results, failures = [], []
    for arch, shp in pairs:
        for mp in meshes:
            try:
                results.append(run_one(arch, shp, multi_pod=mp,
                                       seq_shard=seq_shard,
                                       with_costs=not args.no_costs))
            except Exception as e:  # noqa: BLE001 — a failure IS the signal
                print(f"FAILED [{arch} x {shp} mp={mp}]: {e}",
                      file=sys.stderr)
                failures.append({"arch": arch, "shape": shp,
                                 "multi_pod": mp, "error": str(e)[:2000]})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=1)
    print(f"\n{len(results)} ok, {len(failures)} failed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
