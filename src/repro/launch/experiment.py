"""Deployment-lab launcher: re-run the paper's provider x machine grid
against the live serving engine and diff the result against the paper.

  # CI smoke: 2 profiles x 1 ladder scenario on the tiny GECToR encoder
  PYTHONPATH=src python -m repro.launch.experiment --smoke

  # a bigger CPU-machine grid, 3 repeats, plus a decoder staggered run
  PYTHONPATH=src python -m repro.launch.experiment \
      --profiles AWS/A AWS/C GCP/C --ladder 1 4 16 64 --repeats 3 \
      --staggered --arch qwen2-0.5b

Artifacts (written to --out-dir):
  EXPERIMENT_grid.jsonl   one ExperimentRecord per (profile x scenario)
  EXPERIMENT_drift.json   drift_report(): measured $/1M sentences,
                          cheapest-SLO machine, GPU-vs-CPU premium and the
                          findings ledger, each diffed vs core.analysis
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.loadtest import mixed_bucket_prompts
from repro.deploy.profiles import paper_profiles, profile_by_key
from repro.deploy.report import drift_report, format_drift, write_report
from repro.deploy.runner import (KIND_LADDER, KIND_STAGGERED,
                                 ExperimentRunner, WorkloadScenario,
                                 smoke_grid_profiles)
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine

GRID_FILE = "EXPERIMENT_grid.jsonl"
DRIFT_FILE = "EXPERIMENT_drift.json"


def make_engine_factory(args):
    """(scenario) -> (engine, sentences, sampling) on the chosen arch.

    Encoder scenarios run the paper's workload (GECToR); decoder scenarios
    run --arch through the continuous scheduler so the experiment exercises
    the serving path every scaling PR touches.
    """
    def factory(scenario: WorkloadScenario):
        decoder = scenario.mode == "decoder"
        shared = scenario.name.startswith("staggered_shared")
        arch = args.arch if decoder else "gector-base"
        cfg = get_config(arch, smoke=args.smoke)
        params = init_params(cfg, jax.random.PRNGKey(0))
        sd = scenario.name.endswith("_sd")
        draft = None
        if sd:
            # self-drafting: the draft is the target's own first layer
            # (plus the shared embeddings/head) — no second checkpoint to
            # ship, and the layer keeps the target's vocab and widths, so
            # the pair prices speculation as a pure engine knob
            import dataclasses
            dcfg = dataclasses.replace(cfg, name=f"{cfg.name}-draft",
                                       n_layers=len(cfg.pattern))
            dparams = dict(params)
            dparams["blocks"] = jax.tree.map(lambda x: x[:1],
                                             params["blocks"])
            draft = (dcfg, dparams)
        # decoder scenarios serve the mixed-length traffic the paper's
        # corpus actually has: prompts alternating two pad buckets through
        # the multi-lane scheduler, long prompts prefilling in chunks,
        # decode segments compacted to lane occupancy (--segment-width
        # fixed keeps the full-width A/B baseline)
        buckets = ((args.bucket // 2, args.bucket) if decoder
                   else (args.bucket,))
        quant = "int8" if scenario.name.endswith("_q8") else None
        eng = ServingEngine(cfg, params, EngineConfig(
            mode=scenario.mode, max_batch=args.max_batch,
            pad_buckets=buckets,
            max_new_tokens=scenario.max_new_tokens,
            max_inflight=args.max_inflight,
            prefill_chunk=max(args.bucket // 4, 8) if decoder else None,
            segment_width=args.segment_width,
            prefix_cache=scenario.name.endswith("_pc"),
            weight_quant=quant, kv_quant=quant,
            spec_decode=sd, spec_k=args.spec_k), draft=draft)
        if shared:
            # the prefix-cache A/B cell: every request re-sends the same
            # long system prompt plus a short unique suffix — the traffic
            # shape whose prefill cost the prefix store amortizes
            rng = np.random.default_rng(args.seed)
            sysprompt = rng.integers(0, cfg.vocab_size,
                                     (args.bucket * 3 // 4,))
            sentences = [np.concatenate([
                sysprompt,
                rng.integers(0, cfg.vocab_size,
                             (int(rng.integers(1, args.bucket // 8 + 1)),))])
                for _ in range(64)]
        elif decoder:
            sentences = mixed_bucket_prompts(buckets, 64, cfg.vocab_size,
                                             rng_seed=args.seed)
        else:
            rng = np.random.default_rng(args.seed)
            sentences = [rng.integers(0, cfg.vocab_size,
                                      (int(rng.integers(8, args.bucket // 2
                                                        + 8)),))
                         for _ in range(64)]
        # compile every batch and bucket shape here, not inside the first
        # profile's measured window — including the first-traffic alloc
        # warm-in warmup() now fronts (staging pools, prefix stores), so
        # the staggered rows need no sacrificial traffic before measuring
        eng.warmup()
        sampling = (SamplingParams(max_new_tokens=scenario.max_new_tokens)
                    if scenario.mode == "decoder" else None)
        return eng, sentences, sampling
    return factory


def build_scenarios(args) -> list:
    scenarios = [WorkloadScenario(name="ladder", kind=KIND_LADDER,
                                  mode="encoder",
                                  ladder=tuple(args.ladder),
                                  repeats=args.repeats)]
    if args.staggered:
        scenarios.append(WorkloadScenario(
            name="staggered", kind=KIND_STAGGERED, mode="decoder",
            n_requests=args.requests, gap_s=args.gap,
            max_new_tokens=args.max_new_tokens))
    if args.prefix_cache:
        # A/B pair at equal offered load: same shared-prompt traffic,
        # prefix cache off vs on — the grid cell that prices what
        # shared-prefix KV reuse is worth on each machine
        for name in ("staggered_shared", "staggered_shared_pc"):
            scenarios.append(WorkloadScenario(
                name=name, kind=KIND_STAGGERED, mode="decoder",
                n_requests=args.requests, gap_s=args.gap,
                max_new_tokens=args.max_new_tokens))
    if args.quant:
        # quantized-serving A/B pair at equal offered load: same
        # mixed-bucket traffic, int8 weights + int8 KV vs the bf16/f32
        # default — the grid cell pricing the paper's cache-dominance
        # finding (footprint, not FLOPs, decides the cheapest profile)
        for name in ("staggered_quant", "staggered_quant_q8"):
            scenarios.append(WorkloadScenario(
                name=name, kind=KIND_STAGGERED, mode="decoder",
                n_requests=args.requests, gap_s=args.gap,
                max_new_tokens=args.max_new_tokens))
    if args.spec_decode:
        # speculative-decoding A/B pair at equal offered load: same
        # mixed-bucket traffic, draft-and-verify rounds (self-drafted
        # from the target's first layer) vs plain decode segments — the
        # grid cell pricing what speculation is worth per machine, with
        # the measured accept rate alongside (the knob's value is
        # workload-dependent, so the cell must carry it)
        for name in ("staggered_spec", "staggered_spec_sd"):
            scenarios.append(WorkloadScenario(
                name=name, kind=KIND_STAGGERED, mode="decoder",
                n_requests=args.requests, gap_s=args.gap,
                max_new_tokens=args.max_new_tokens))
    return scenarios


def prefix_cache_cells(records) -> list:
    """$/1M-requests for the staggered_shared A/B pair, per profile — the
    deploy-lab cell recording what the prefix cache is worth at equal
    offered load (same gap, same prompts; only the engine knob differs)."""
    by_key = {}
    for rec in records:
        d = rec.to_dict() if hasattr(rec, "to_dict") else rec
        name = d["scenario"]["name"]
        if not name.startswith("staggered_shared"):
            continue
        prof = d["profile"]
        cell = d["cells"][0]
        usd_hr = prof["hourly_cost_usd"]
        rps = cell["requests_per_s"]
        by_key.setdefault(f"{prof['provider']}/{prof['machine']}", {})[
            "pc" if name.endswith("_pc") else "off"] = {
                "usd_per_1m_requests": usd_hr / 3600.0 / max(rps, 1e-9)
                                       * 1e6,
                "requests_per_s": rps,
                "prefill_mean_s": cell["prefill_mean_s"]}
    out = []
    for key, pair in sorted(by_key.items()):
        if "off" not in pair or "pc" not in pair:
            continue
        off, pc = pair["off"], pair["pc"]
        out.append({
            "profile": key,
            "usd_per_1m_requests_off": off["usd_per_1m_requests"],
            "usd_per_1m_requests_pc": pc["usd_per_1m_requests"],
            "usd_drop_pct": 100.0 * (1 - pc["usd_per_1m_requests"]
                                     / max(off["usd_per_1m_requests"],
                                           1e-12)),
            "prefill_mean_off_s": off["prefill_mean_s"],
            "prefill_mean_pc_s": pc["prefill_mean_s"]})
    return out


def quant_cells(records) -> list:
    """$/1M-requests and resident-memory footprint for the staggered_quant
    A/B pair, per profile — the deploy-lab cell pricing the memory-
    footprint reduction (weights + lane KV) quantization buys at equal
    offered load. Footprint comes from the record's self-describing
    ``engine`` dict (weight_bytes) plus the lane kv_bytes gauges in its
    engine window."""
    by_key = {}
    for rec in records:
        d = rec.to_dict() if hasattr(rec, "to_dict") else rec
        name = d["scenario"]["name"]
        if not name.startswith("staggered_quant"):
            continue
        prof = d["profile"]
        cell = d["cells"][0]
        usd_hr = prof["hourly_cost_usd"]
        rps = cell["requests_per_s"]
        lanes = d["engine_window"].get("lanes", {})
        footprint = (d["engine"]["weight_bytes"]
                     + sum(s.get("kv_bytes", 0) for s in lanes.values()))
        by_key.setdefault(f"{prof['provider']}/{prof['machine']}", {})[
            "q8" if name.endswith("_q8") else "off"] = {
                "usd_per_1m_requests": usd_hr / 3600.0 / max(rps, 1e-9)
                                       * 1e6,
                "requests_per_s": rps,
                "footprint_bytes": footprint,
                "tokens_per_s": cell["tokens_per_s"]}
    out = []
    for key, pair in sorted(by_key.items()):
        if "off" not in pair or "q8" not in pair:
            continue
        off, q8 = pair["off"], pair["q8"]
        out.append({
            "profile": key,
            "usd_per_1m_requests_off": off["usd_per_1m_requests"],
            "usd_per_1m_requests_q8": q8["usd_per_1m_requests"],
            "usd_drop_pct": 100.0 * (1 - q8["usd_per_1m_requests"]
                                     / max(off["usd_per_1m_requests"],
                                           1e-12)),
            "footprint_bytes_off": off["footprint_bytes"],
            "footprint_bytes_q8": q8["footprint_bytes"],
            "footprint_ratio": off["footprint_bytes"]
                               / max(q8["footprint_bytes"], 1),
            "tokens_per_s_off": off["tokens_per_s"],
            "tokens_per_s_q8": q8["tokens_per_s"]})
    return out


def spec_decode_cells(records) -> list:
    """$/1M-requests and accept rate for the staggered_spec A/B pair, per
    profile — the deploy-lab cell pricing speculative decoding at equal
    offered load. The accept rate comes from the record's engine window
    (per-lane spec_proposed/spec_accepted counters): a cell's cost delta
    only transfers to workloads with a comparable accept rate, so the
    ledger carries both."""
    by_key = {}
    for rec in records:
        d = rec.to_dict() if hasattr(rec, "to_dict") else rec
        name = d["scenario"]["name"]
        if not name.startswith("staggered_spec"):
            continue
        prof = d["profile"]
        cell = d["cells"][0]
        usd_hr = prof["hourly_cost_usd"]
        rps = cell["requests_per_s"]
        lanes = d["engine_window"].get("lanes", {})
        prop = sum(s.get("spec_proposed", 0) for s in lanes.values())
        acc = sum(s.get("spec_accepted", 0) for s in lanes.values())
        by_key.setdefault(f"{prof['provider']}/{prof['machine']}", {})[
            "sd" if name.endswith("_sd") else "off"] = {
                "usd_per_1m_requests": usd_hr / 3600.0 / max(rps, 1e-9)
                                       * 1e6,
                "requests_per_s": rps,
                "tokens_per_s": cell["tokens_per_s"],
                "accept_rate": acc / prop if prop else 0.0}
    out = []
    for key, pair in sorted(by_key.items()):
        if "off" not in pair or "sd" not in pair:
            continue
        off, sd = pair["off"], pair["sd"]
        out.append({
            "profile": key,
            "usd_per_1m_requests_off": off["usd_per_1m_requests"],
            "usd_per_1m_requests_sd": sd["usd_per_1m_requests"],
            "usd_drop_pct": 100.0 * (1 - sd["usd_per_1m_requests"]
                                     / max(off["usd_per_1m_requests"],
                                           1e-12)),
            "tokens_per_s_off": off["tokens_per_s"],
            "tokens_per_s_sd": sd["tokens_per_s"],
            "accept_rate": sd["accept_rate"]})
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid: 2 profiles x (1,2) ladder, smoke "
                         "configs — the CI acceptance run")
    ap.add_argument("--profiles", nargs="*", default=None,
                    metavar="PROV/MACHINE",
                    help="profile keys (e.g. AWS/C); default: smoke pair "
                         "with --smoke, all 21 paper profiles otherwise")
    ap.add_argument("--ladder", type=int, nargs="*", default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--staggered", action="store_true",
                    help="add the open-loop decoder scenario")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="add the shared-prompt staggered A/B pair "
                         "(prefix_cache off vs on) and report the "
                         "$/1M-requests drop per profile")
    ap.add_argument("--quant", action="store_true",
                    help="add the quantized-serving staggered A/B pair "
                         "(int8 weights + int8 KV vs bf16/f32) and report "
                         "the per-profile footprint + $/1M-requests delta")
    ap.add_argument("--spec-decode", action="store_true",
                    help="add the speculative-decoding staggered A/B pair "
                         "(draft-and-verify vs plain decode) and report "
                         "the per-profile $/1M-requests delta plus the "
                         "measured accept rate")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify round for the "
                         "--spec-decode pair")
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=ARCHS + ["gector-base"],
                    help="decoder arch for --staggered")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gap", type=float, default=0.05)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-inflight", type=int, default=None)
    ap.add_argument("--bucket", type=int, default=32,
                    help="pad bucket (and prompt-length ceiling)")
    ap.add_argument("--segment-width", default="adaptive",
                    choices=("adaptive", "fixed"),
                    help="decoder decode-segment widths: occupancy-"
                         "adaptive tiers (default) or the fixed "
                         "max_batch-wide A/B baseline — so the grid "
                         "measures the tier effect (docs/DEPLOY_LAB.md)")
    ap.add_argument("--target-ns", type=int, default=None,
                    help="NS for the cheapest-SLO question (default: the "
                         "largest ladder cell actually run)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=".")
    args = ap.parse_args(argv)

    if args.smoke:
        args.ladder = args.ladder or [1, 2]
        args.repeats = args.repeats or 1
        profiles = ([profile_by_key(k) for k in args.profiles]
                    if args.profiles else list(smoke_grid_profiles()))
    else:
        args.ladder = args.ladder or [1, 4, 16]
        args.repeats = args.repeats or 2
        profiles = ([profile_by_key(k) for k in args.profiles]
                    if args.profiles else list(paper_profiles()))
        args.smoke = True   # configs stay CPU-sized; the grid is the knob

    os.makedirs(args.out_dir, exist_ok=True)
    grid_path = os.path.join(args.out_dir, GRID_FILE)
    drift_path = os.path.join(args.out_dir, DRIFT_FILE)

    # the factory already compiles every batch shape; skip the runner's
    # generic single-request warmup so scenarios start immediately
    runner = ExperimentRunner(make_engine_factory(args), seed=args.seed,
                              warmup=False)
    records = runner.run_grid(profiles, build_scenarios(args),
                              out_path=grid_path,
                              progress=lambda msg: print(f"[run] {msg}",
                                                         flush=True))
    report = drift_report(records, target_ns=args.target_ns)
    if args.prefix_cache:
        report["prefix_cache"] = prefix_cache_cells(records)
    if args.quant:
        report["quant"] = quant_cells(records)
    if args.spec_decode:
        report["spec_decode"] = spec_decode_cells(records)
    write_report(report, drift_path)
    print(f"[out] {grid_path} ({len(records)} records)")
    print(f"[out] {drift_path}")
    print(format_drift(report))
    for cell in report.get("prefix_cache", []):
        print(f"prefix-cache {cell['profile']}: "
              f"${cell['usd_per_1m_requests_off']:.2f} -> "
              f"${cell['usd_per_1m_requests_pc']:.2f} per 1M requests "
              f"({cell['usd_drop_pct']:+.1f}% cheaper), prefill mean "
              f"{cell['prefill_mean_off_s']*1e3:.1f} -> "
              f"{cell['prefill_mean_pc_s']*1e3:.1f} ms")
    for cell in report.get("quant", []):
        print(f"quant {cell['profile']}: "
              f"${cell['usd_per_1m_requests_off']:.2f} -> "
              f"${cell['usd_per_1m_requests_q8']:.2f} per 1M requests "
              f"({cell['usd_drop_pct']:+.1f}%), footprint "
              f"{cell['footprint_bytes_off']} -> "
              f"{cell['footprint_bytes_q8']} bytes "
              f"({cell['footprint_ratio']:.2f}x smaller)")
    for cell in report.get("spec_decode", []):
        print(f"spec-decode {cell['profile']}: "
              f"${cell['usd_per_1m_requests_off']:.2f} -> "
              f"${cell['usd_per_1m_requests_sd']:.2f} per 1M requests "
              f"({cell['usd_drop_pct']:+.1f}%), "
              f"{cell['tokens_per_s_off']:.1f} -> "
              f"{cell['tokens_per_s_sd']:.1f} tok/s, accept rate "
              f"{cell['accept_rate']:.2f}")


if __name__ == "__main__":
    main()
