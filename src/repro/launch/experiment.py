"""Deployment-lab launcher: re-run the paper's provider x machine grid
against the live serving engine and diff the result against the paper.

  # CI smoke: 2 profiles x 1 ladder scenario on the tiny GECToR encoder
  PYTHONPATH=src python -m repro.launch.experiment --smoke

  # a bigger CPU-machine grid, 3 repeats, plus a decoder staggered run
  PYTHONPATH=src python -m repro.launch.experiment \
      --profiles AWS/A AWS/C GCP/C --ladder 1 4 16 64 --repeats 3 \
      --staggered --arch qwen2-0.5b

Artifacts (written to --out-dir):
  EXPERIMENT_grid.jsonl   one ExperimentRecord per (profile x scenario)
  EXPERIMENT_drift.json   drift_report(): measured $/1M sentences,
                          cheapest-SLO machine, GPU-vs-CPU premium and the
                          findings ledger, each diffed vs core.analysis
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.loadtest import mixed_bucket_prompts
from repro.deploy.profiles import paper_profiles, profile_by_key
from repro.deploy.report import drift_report, format_drift, write_report
from repro.deploy.runner import (KIND_LADDER, KIND_STAGGERED,
                                 ExperimentRunner, WorkloadScenario,
                                 smoke_grid_profiles)
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine

GRID_FILE = "EXPERIMENT_grid.jsonl"
DRIFT_FILE = "EXPERIMENT_drift.json"


def make_engine_factory(args):
    """(scenario) -> (engine, sentences, sampling) on the chosen arch.

    Encoder scenarios run the paper's workload (GECToR); decoder scenarios
    run --arch through the continuous scheduler so the experiment exercises
    the serving path every scaling PR touches.
    """
    def factory(scenario: WorkloadScenario):
        decoder = scenario.mode == "decoder"
        arch = args.arch if decoder else "gector-base"
        cfg = get_config(arch, smoke=args.smoke)
        params = init_params(cfg, jax.random.PRNGKey(0))
        # decoder scenarios serve the mixed-length traffic the paper's
        # corpus actually has: prompts alternating two pad buckets through
        # the multi-lane scheduler, long prompts prefilling in chunks,
        # decode segments compacted to lane occupancy (--segment-width
        # fixed keeps the full-width A/B baseline)
        buckets = ((args.bucket // 2, args.bucket) if decoder
                   else (args.bucket,))
        eng = ServingEngine(cfg, params, EngineConfig(
            mode=scenario.mode, max_batch=args.max_batch,
            pad_buckets=buckets,
            max_new_tokens=scenario.max_new_tokens,
            max_inflight=args.max_inflight,
            prefill_chunk=max(args.bucket // 4, 8) if decoder else None,
            segment_width=args.segment_width))
        if decoder:
            sentences = mixed_bucket_prompts(buckets, 64, cfg.vocab_size,
                                             rng_seed=args.seed)
        else:
            rng = np.random.default_rng(args.seed)
            sentences = [rng.integers(0, cfg.vocab_size,
                                      (int(rng.integers(8, args.bucket // 2
                                                        + 8)),))
                         for _ in range(64)]
        # compile every batch and bucket shape here, not inside the first
        # profile's measured window (the grid's first row would otherwise
        # carry seconds of compile latency the later rows don't)
        eng.warmup()
        if decoder:
            # warmup() primes the jit caches but serves no traffic; the
            # first real requests still pay a residual warm-in the
            # jit_compiles counter cannot see (lazy staging-pool allocs,
            # thread pools — measured ~20x on the first staggered row,
            # pre-existing). Absorb it with one short + one chunk-
            # prefilled request, then clear the samples they left, as
            # run_ladder(warmup=True) does for ladder cells.
            for p in (sentences[0], max(sentences[:4], key=len)):
                eng.generate(p, SamplingParams(max_new_tokens=2)
                             ).result(timeout=600)
            eng.discard_samples()
        sampling = (SamplingParams(max_new_tokens=scenario.max_new_tokens)
                    if scenario.mode == "decoder" else None)
        return eng, sentences, sampling
    return factory


def build_scenarios(args) -> list:
    scenarios = [WorkloadScenario(name="ladder", kind=KIND_LADDER,
                                  mode="encoder",
                                  ladder=tuple(args.ladder),
                                  repeats=args.repeats)]
    if args.staggered:
        scenarios.append(WorkloadScenario(
            name="staggered", kind=KIND_STAGGERED, mode="decoder",
            n_requests=args.requests, gap_s=args.gap,
            max_new_tokens=args.max_new_tokens))
    return scenarios


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid: 2 profiles x (1,2) ladder, smoke "
                         "configs — the CI acceptance run")
    ap.add_argument("--profiles", nargs="*", default=None,
                    metavar="PROV/MACHINE",
                    help="profile keys (e.g. AWS/C); default: smoke pair "
                         "with --smoke, all 21 paper profiles otherwise")
    ap.add_argument("--ladder", type=int, nargs="*", default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--staggered", action="store_true",
                    help="add the open-loop decoder scenario")
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=ARCHS + ["gector-base"],
                    help="decoder arch for --staggered")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gap", type=float, default=0.05)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-inflight", type=int, default=None)
    ap.add_argument("--bucket", type=int, default=32,
                    help="pad bucket (and prompt-length ceiling)")
    ap.add_argument("--segment-width", default="adaptive",
                    choices=("adaptive", "fixed"),
                    help="decoder decode-segment widths: occupancy-"
                         "adaptive tiers (default) or the fixed "
                         "max_batch-wide A/B baseline — so the grid "
                         "measures the tier effect (docs/DEPLOY_LAB.md)")
    ap.add_argument("--target-ns", type=int, default=None,
                    help="NS for the cheapest-SLO question (default: the "
                         "largest ladder cell actually run)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=".")
    args = ap.parse_args(argv)

    if args.smoke:
        args.ladder = args.ladder or [1, 2]
        args.repeats = args.repeats or 1
        profiles = ([profile_by_key(k) for k in args.profiles]
                    if args.profiles else list(smoke_grid_profiles()))
    else:
        args.ladder = args.ladder or [1, 4, 16]
        args.repeats = args.repeats or 2
        profiles = ([profile_by_key(k) for k in args.profiles]
                    if args.profiles else list(paper_profiles()))
        args.smoke = True   # configs stay CPU-sized; the grid is the knob

    os.makedirs(args.out_dir, exist_ok=True)
    grid_path = os.path.join(args.out_dir, GRID_FILE)
    drift_path = os.path.join(args.out_dir, DRIFT_FILE)

    # the factory already compiles every batch shape; skip the runner's
    # generic single-request warmup so scenarios start immediately
    runner = ExperimentRunner(make_engine_factory(args), seed=args.seed,
                              warmup=False)
    records = runner.run_grid(profiles, build_scenarios(args),
                              out_path=grid_path,
                              progress=lambda msg: print(f"[run] {msg}",
                                                         flush=True))
    report = drift_report(records, target_ns=args.target_ns)
    write_report(report, drift_path)
    print(f"[out] {grid_path} ({len(records)} records)")
    print(f"[out] {drift_path}")
    print(format_drift(report))


if __name__ == "__main__":
    main()
