"""Serving launcher: stands up the MLaaS engine for any arch (decoder modes)
or GECToR (encoder mode) and optionally runs the load-test ladder against
it — the deployable version of examples/serve_poc.py.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 8 --temperature 0.7 --stream

Decoder requests go through the v2 API (GenerationRequest -> RequestHandle
-> GenerationResult) and are served by the step-level continuous-batching
scheduler unless --no-continuous selects the batch-at-a-time worker.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.loadtest import format_table, run_ladder
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.training.checkpoint import restore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gector-base",
                    choices=ARCHS + ["gector-base"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--ladder", type=int, nargs="*", default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-inflight", type=int, default=None)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="print the first request's tokens as they arrive")
    ap.add_argument("--no-continuous", action="store_true",
                    help="batch-at-a-time decoder worker (A/B baseline)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.ckpt:
        params = restore(args.ckpt)["params"]
        if "encoder" in params:          # gector checkpoint
            params = params["encoder"]
    else:
        params = init_params(cfg, jax.random.PRNGKey(0))
    mode = "encoder" if cfg.arch_type == "encoder" else "decoder"
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode=mode, max_batch=args.max_batch,
                                     max_inflight=args.max_inflight,
                                     max_new_tokens=args.max_new_tokens,
                                     continuous=not args.no_continuous))
    try:
        sentences = [np.random.randint(0, cfg.vocab_size,
                                       (np.random.randint(8, 32),))
                     for _ in range(max(args.requests, 32))]
        if args.ladder:
            cells = run_ladder(eng, sentences, ladder=tuple(args.ladder),
                               repeats=1)
            print(format_table(cells))
        elif mode == "decoder":
            sp = SamplingParams(eos_id=args.eos_id,
                                temperature=args.temperature,
                                top_k=args.top_k, seed=args.seed)
            handles = [eng.generate(s, sp)
                       for s in sentences[: args.requests]]
            if args.stream and handles:
                print("request[0] stream:", end=" ", flush=True)
                for tok in handles[0]:
                    print(tok, end=" ", flush=True)
                print()
            res = None
            for h in handles:
                res = h.result(timeout=600)
            if res is not None:
                t = res.timing
                print(f"last request: {len(res.tokens)} tokens, "
                      f"finish={res.finish_reason}, "
                      f"queue {t.queue_s * 1e3:.1f}ms"
                      f" | prefill {t.prefill_s * 1e3:.1f}ms"
                      f" | decode {t.decode_s * 1e3:.1f}ms")
            print("metrics:", eng.metrics())
        else:
            futs = [eng.submit(s) for s in sentences[: args.requests]]
            for f in futs:
                f.result(timeout=600)
            print("metrics:", eng.metrics())
    finally:
        eng.close()


if __name__ == "__main__":
    main()
