"""Serving launcher: stands up the MLaaS engine for any arch (decoder modes)
or GECToR (encoder mode) and optionally runs the load-test ladder against
it — the deployable version of examples/serve_poc.py.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.loadtest import format_table, run_ladder
from repro.models import init_params
from repro.serving import EngineConfig, ServingEngine
from repro.training.checkpoint import restore


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gector-base",
                    choices=ARCHS + ["gector-base"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--ladder", type=int, nargs="*", default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-inflight", type=int, default=None)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.ckpt:
        params = restore(args.ckpt)["params"]
        if "encoder" in params:          # gector checkpoint
            params = params["encoder"]
    else:
        params = init_params(cfg, jax.random.PRNGKey(0))
    mode = "encoder" if cfg.arch_type == "encoder" else "decoder"
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode=mode, max_batch=args.max_batch,
                                     max_inflight=args.max_inflight,
                                     max_new_tokens=args.max_new_tokens))
    try:
        sentences = [np.random.randint(0, cfg.vocab_size,
                                       (np.random.randint(8, 32),))
                     for _ in range(max(args.requests, 32))]
        if args.ladder:
            cells = run_ladder(eng, sentences, ladder=tuple(args.ladder),
                               repeats=1)
            print(format_table(cells))
        else:
            futs = [eng.submit(s) for s in sentences[: args.requests]]
            for f in futs:
                f.result(timeout=600)
            print("metrics:", eng.metrics())
    finally:
        eng.close()


if __name__ == "__main__":
    main()
