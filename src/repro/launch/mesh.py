"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2x16x16 = 512 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the launch path."""
    return jax.make_mesh((1, 1), ("data", "model"))
