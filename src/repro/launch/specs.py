"""ShapeDtypeStruct input specs and sharding trees for every
(architecture x input shape x mesh) combination — the dry-run's core.

Nothing here allocates device memory: parameters/optimizer/caches come from
``jax.eval_shape`` and inputs are ShapeDtypeStructs, so full-scale configs
(27B params, 500k-token caches) lower on a CPU host.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import init_params, make_caches
from repro.models.config import ModelConfig, ShapeConfig
from repro.parallel.sharding import (MeshRules, param_partition_specs,
                                     rules_for)
from repro.training.optimizer import adamw_init, opt_state_specs
from repro.training.train_loop import train_step


# ----------------------------------------------------------- shape helpers
def _batch_axes(rules: MeshRules):
    return rules.batch_axes if len(rules.batch_axes) > 1 \
        else rules.batch_axes[0]


def _batch_size_divisible(rules: MeshRules, b: int) -> bool:
    n = 1
    for a in rules.batch_axes:
        n *= rules.axis_size(a)
    return b % n == 0 and b >= n


def batch_spec(rules: MeshRules, b: int, extra=(None,)) -> P:
    if _batch_size_divisible(rules, b):
        return P(_batch_axes(rules), *extra)
    return P(None, *extra)


# ------------------------------------------------------------ cache specs
def cache_partition_specs(cfg: ModelConfig, cache_shapes, rules: MeshRules,
                          batch: int):
    """Specs for the stacked cache pytree. If the batch dim is divisible by
    the data axes it is sharded there; otherwise (long_500k, B=1) attention
    cache *sequence* dims shard over the data axes instead (cache sequence
    parallelism). KV head dims shard over 'model' when divisible."""
    seq_shard = not _batch_size_divisible(rules, batch)
    b_ax = None if seq_shard else _batch_axes(rules)
    s_ax = _batch_axes(rules) if seq_shard else None
    msize = rules.axis_size(rules.model_axis)
    kv_ax = rules.model_axis if (cfg.n_kv_heads % msize == 0
                                 and rules.shard_attn_heads) else None
    # when kv heads can't shard (GQA kv < axis, e.g. stablelm kv=8), shard
    # the cache *sequence* over the model axis — otherwise a decode_32k
    # cache replicates on the model axis (111 GiB/device for stablelm-12b).
    # (head_dim sharding was tried first and refuted: GSPMD all-gathers the
    # fp32-converted cache for the QK contraction — §Perf iteration A.)
    kv_seq_ax = (rules.model_axis if kv_ax is None else None)

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name in ("ck", "cv"):      # cross-KV (P, B, T_enc, Hkv, hd)
            return P(None, b_ax, None, kv_ax, None)
        if name in ("k", "v"):        # (P, B, L, Hkv, hd)
            L = leaf.shape[2]
            s = s_ax if (s_ax and L % _bs(rules) == 0) else None
            if s is None and kv_seq_ax and L % msize == 0:
                s = kv_seq_ax
            return P(None, b_ax, s, kv_ax, None)
        if name == "pos":             # (P, B, L)
            L = leaf.shape[2]
            s = s_ax if (s_ax and L % _bs(rules) == 0) else None
            if s is None and kv_seq_ax and L % msize == 0:
                s = kv_seq_ax
            return P(None, b_ax, s)
        if name == "len":             # (P, B)
            return P(None, b_ax)
        if name == "C":               # mlstm (P, B, nh, hd, hd)
            return P(None, b_ax, None, None, None)
        if name in ("n", "m", "c", "h"):
            if nd == 3 and name == "h":   # rglru h: (P, B, W)
                w = leaf.shape[-1]
                return P(None, b_ax,
                         rules.model_axis if w % msize == 0 else None)
            return P(*([None, b_ax] + [None] * (nd - 2)))
        if name == "conv":            # (P, B, 3, W)
            w = leaf.shape[-1]
            return P(None, b_ax, None,
                     rules.model_axis if w % msize == 0 else None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def _bs(rules: MeshRules) -> int:
    n = 1
    for a in rules.batch_axes:
        n *= rules.axis_size(a)
    return n


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.mode == "train":
        s_text = S - cfg.vis_tokens
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text + 1), jnp.int32)
        if cfg.vis_tokens:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vis_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.enc_layers:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
    elif shape.mode == "prefill":
        s_text = S - cfg.vis_tokens
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
        if cfg.vis_tokens:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vis_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.enc_layers:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
    else:  # decode: ONE new token against a seq_len KV cache. Enc-dec
        # models need no encoder input — cross-KV is cached at prefill.
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        specs["positions"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return specs


def input_shardings(cfg, shape, rules: MeshRules):
    mesh = rules.mesh
    B = shape.global_batch
    out = {}
    for name, sds in input_specs(cfg, shape).items():
        extra = (None,) * (len(sds.shape) - 1)
        out[name] = NamedSharding(mesh, batch_spec(rules, B, extra))
    return out


# -------------------------------------------------------------- step fns
def param_shapes(cfg) -> dict:
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def build_train(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules, *,
                oc=None, seq_shard: bool = False):
    """Returns (fn, arg_specs, in_shardings) for jit-lowering train_step."""
    from repro.training.optimizer import OptConfig
    oc = oc or OptConfig()
    mesh = rules.mesh
    pshapes = param_shapes(cfg)
    pspecs = param_partition_specs(pshapes, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    oshapes = jax.eval_shape(adamw_init, pshapes)
    ospecs = opt_state_specs(pspecs, pshapes, rules)
    oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                          is_leaf=lambda x: isinstance(x, P))
    bshard = input_shardings(cfg, shape, rules)
    # map engine input names to train batch keys
    bshard = {{"enc_embeds": "enc_embeds"}.get(k, k): v
              for k, v in bshard.items()}

    def fn(params, opt_state, batch):
        return train_step(cfg, oc, params, opt_state, batch, remat=True,
                          seq_shard=seq_shard)

    args = (pshapes, jax.eval_shape(adamw_init, pshapes),
            input_specs(cfg, shape))
    return fn, args, (pshard, oshard, bshard)


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules):
    from repro.models import forward
    mesh = rules.mesh
    B, S = shape.global_batch, shape.seq_len
    pshapes = param_shapes(cfg)
    pspecs = param_partition_specs(pshapes, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    cshapes = jax.eval_shape(
        lambda: make_caches(cfg, B, min(S, cfg.max_seq_len)))
    cspecs = cache_partition_specs(cfg, cshapes, rules, B)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                          is_leaf=lambda x: isinstance(x, P))
    ishard = input_shardings(cfg, shape, rules)

    def fn(params, caches, inputs):
        kw = {}
        if "prefix_embeds" in inputs:
            kw["prefix_embeds"] = inputs["prefix_embeds"]
        if "enc_embeds" in inputs:
            kw["enc_tokens_embeds"] = inputs["enc_embeds"]
        logits, caches, _ = forward(cfg, params, tokens=inputs["tokens"],
                                    caches=caches, mode="full", **kw)
        # serving prefill returns only the last-position logits
        return logits[:, -1], caches

    args = (pshapes, cshapes, input_specs(cfg, shape))
    return fn, args, (pshard, cshard, ishard)


def build_decode(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules):
    from repro.models import decode_step
    mesh = rules.mesh
    B, S = shape.global_batch, shape.seq_len
    long_ctx = shape.name == "long_500k"
    pshapes = param_shapes(cfg)
    pspecs = param_partition_specs(pshapes, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    cshapes = jax.eval_shape(
        lambda: make_caches(cfg, B, S, long_ctx=long_ctx))
    cspecs = cache_partition_specs(cfg, cshapes, rules, B)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                          is_leaf=lambda x: isinstance(x, P))
    ishard = input_shardings(cfg, shape, rules)

    def fn(params, caches, inputs):
        kw = {}
        if "enc_embeds" in inputs:
            kw["enc_tokens_embeds"] = inputs["enc_embeds"]
        logits, caches, _ = decode_step(cfg, params, inputs["tokens"],
                                        inputs["positions"], caches,
                                        long_ctx=long_ctx, **kw)
        return logits[:, 0], caches

    args = (pshapes, cshapes, input_specs(cfg, shape))
    return fn, args, (pshard, cshard, ishard)


def build_step(cfg, shape, rules, **kw):
    if shape.mode == "train":
        return build_train(cfg, shape, rules, **kw)
    if shape.mode == "prefill":
        return build_prefill(cfg, shape, rules)
    return build_decode(cfg, shape, rules)
