"""Quantized serving subsystem: int8 weights + int8 KV cache.

Three layers (see docs/ARCHITECTURE.md, "Quantized serving path"):

  * ``quant.weights`` — symmetric per-channel int8 weight quantization
    (``quantize_params``) and the ``qeinsum`` apply-site dispatcher the
    model projections call.
  * ``quant.policy`` — which layer classes quantize (attn projections +
    MLP; embeddings/norms/MoE stay in float).
  * ``quant.kv`` — per-(position, head) int8 KV cache quantize/dequantize
    used by ``models.attention`` and threaded through ``serving.kvcache``.
"""
from repro.quant.kv import (dequantize_kv, quantize_kv,  # noqa: F401
                            validate_kv_quant)
from repro.quant.policy import (LAYER_CLASSES, QuantPolicy,  # noqa: F401
                                default_policy)
from repro.quant.weights import (dequantize_leaf,  # noqa: F401
                                 dequantize_params, is_quantized,
                                 params_bytes, qeinsum, quantize_leaf,
                                 quantize_params, quantized_leaf_count)
