"""Symmetric per-channel int8 weight quantization.

A quantized leaf is a two-array dict ``{"qw": int8, "scale": float32}``
replacing the float array in the params pytree: ``qw`` keeps the original
shape, ``scale`` keeps only the output-channel axes (one absmax/127 scale
per output channel, symmetric — no zero points). The contraction-axis
count is recoverable as ``qw.ndim - scale.ndim``, so the quantized tree
needs no side-channel metadata: ``lax.scan`` slicing the stacked period
axis, jit donation, and the cache-pool tree maps all see plain arrays.

``qeinsum`` is the apply-site entry point: models' projection einsums call
it instead of ``jnp.einsum`` and it dispatches — float weights take the
exact pre-quantization einsum (the default path stays bit-identical),
quantized dicts take the dequant-fused matmul (scales applied at the fp32
accumulator; no dequantized weight copy is ever materialized, on either
backend — see ``kernels.ops.matmul_q8``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.policy import QuantPolicy, default_policy

# period-stacked subtrees: leaves below carry a leading (n_periods,) batch
# axis that quantization must treat as per-layer, not as a channel
_STACKED_ROOTS = ("blocks", "enc_blocks")


def is_quantized(leaf) -> bool:
    """True for the {'qw', 'scale'} dicts ``quantize_params`` emits."""
    return isinstance(leaf, dict) and "qw" in leaf and "scale" in leaf


def quantize_leaf(w, n_contract: int, n_batch: int = 0) -> dict:
    """w: (*batch, *contract, *out) -> {'qw': int8 same shape,
    'scale': f32 (*batch, *out)}. scale = absmax/127 over the contraction
    axes, per output channel; all-zero channels get scale 0 and quantize
    (and dequantize) to exact zeros."""
    wf = w.astype(jnp.float32)
    caxes = tuple(range(n_batch, n_batch + n_contract))
    amax = jnp.max(jnp.abs(wf), axis=caxes)
    scale = amax / 127.0
    sb = jnp.expand_dims(scale, caxes)
    q = jnp.round(wf / jnp.where(sb > 0, sb, 1.0))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return {"qw": q, "scale": scale}


def dequantize_leaf(leaf, dtype=jnp.float32, n_batch: int = 0):
    """Reconstruct the float weight (round-trip error <= scale/2 per
    element — the property tests' bound). ``n_batch`` must match the
    value quantization used (1 for period-stacked leaves)."""
    qw, scale = leaf["qw"], leaf["scale"]
    nc = qw.ndim - scale.ndim
    caxes = tuple(range(n_batch, n_batch + nc))
    sb = jnp.expand_dims(scale, caxes)
    return (qw.astype(jnp.float32) * sb).astype(dtype)


def quantize_params(params: dict, spec: Optional[QuantPolicy] = None) -> dict:
    """Quantize a model param tree per the policy ``spec`` (default: the
    three matmul layer classes — see ``quant.policy``). Non-selected leaves
    are passed through by reference; the returned tree is structurally a
    drop-in for the float one at every ``qeinsum`` apply site."""
    spec = spec or default_policy()

    def walk(tree, parent, stacked):
        out = {}
        for key, val in tree.items():
            if isinstance(val, dict):
                out[key] = walk(val, key, stacked or key in _STACKED_ROOTS)
            else:
                nc = spec.n_contract(parent, key)
                if nc is None:
                    out[key] = val
                else:
                    out[key] = quantize_leaf(val, nc,
                                             n_batch=1 if stacked else 0)
        return out

    return walk(params, None, False)


def dequantize_params(params: dict, dtype=jnp.float32) -> dict:
    """Invert ``quantize_params`` (up to the per-element scale/2 rounding
    error) — the round-trip half of the property tests."""
    def walk(tree, stacked):
        out = {}
        for key, val in tree.items():
            if is_quantized(val):
                out[key] = dequantize_leaf(val, dtype,
                                           n_batch=1 if stacked else 0)
            elif isinstance(val, dict):
                out[key] = walk(val, stacked or key in _STACKED_ROOTS)
            else:
                out[key] = val
        return out
    return walk(params, False)


def params_bytes(params) -> int:
    """Device bytes of a (possibly quantized) param tree — the
    ``weight_bytes`` gauge the engine reports."""
    return int(sum(x.nbytes for x in jax.tree.leaves(params)))


def quantized_leaf_count(params) -> int:
    n = 0

    def walk(tree):
        nonlocal n
        for val in tree.values():
            if is_quantized(val):
                n += 1
            elif isinstance(val, dict):
                walk(val)
    walk(params)
    return n


def qeinsum(eq: str, x, w):
    """Projection einsum with a possibly-quantized weight operand.

    Float ``w``: exactly ``jnp.einsum(eq, x, w)`` — the default serving
    path keeps its pre-quantization graph bit-for-bit. Quantized ``w``:
    the einsum family models/ uses (contraction over the trailing axes of
    ``x`` = the leading axes of ``w``; outputs = x's batch dims then w's
    output dims, operands in order) collapses to one (M, K) x (K, N)
    matmul, dispatched to the dequant-fused kernel with the (N,) output-
    channel scales applied at the fp32 accumulator.
    """
    if not is_quantized(w):
        return jnp.einsum(eq, x, w)
    from repro.kernels.ops import matmul_q8
    qw, scale = w["qw"], w["scale"]
    nc = qw.ndim - scale.ndim
    lead = x.shape[:x.ndim - nc]
    K = math.prod(x.shape[x.ndim - nc:])
    out_shape = qw.shape[nc:]
    N = math.prod(out_shape)
    out = matmul_q8(x.reshape(-1, K), qw.reshape(K, N),
                    scale.reshape(N))
    return out.reshape(lead + out_shape).astype(x.dtype)
