"""int8 KV-cache quantization: per-(position, kv-head) symmetric scales.

The cache layout grows two f32 scale planes next to the int8 K/V buffers:

    k: (B, L, Hkv, D) int8        k_scale: (B, L, Hkv) f32
    v: (B, L, Hkv, D) int8        v_scale: (B, L, Hkv) f32

One scale per written (position, head) vector — computed at write time
from that vector's absmax, so storing a new token never has to rescale
old entries (a per-slot scale would), and a slot copy (lane gather,
prefix-store load, tier compact/scatter) moves payload + scales with the
same leaf-generic tree map the float pool uses. Empty positions hold zero
payload and zero scale; the ``pos = -1`` sentinel masks them in attention
exactly as in the float cache.
"""
from __future__ import annotations

import jax.numpy as jnp

KV_QUANT_MODES = (None, "int8")


def validate_kv_quant(kv_quant) -> None:
    if kv_quant not in KV_QUANT_MODES:
        raise ValueError(
            f"kv_quant must be one of {KV_QUANT_MODES}, got {kv_quant!r}")


def quantize_kv(x):
    """x: (..., D) float -> (int8 (..., D), f32 scale (...,)). Symmetric
    absmax/127 per trailing vector; all-zero vectors quantize to exact
    zeros with scale 0."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / 127.0
    q = jnp.round(xf / jnp.where(scale > 0, scale, 1.0)[..., None])
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype):
    """Invert ``quantize_kv`` at gather time (attention read path)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
