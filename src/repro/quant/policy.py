"""Which parameter leaves quantize — the per-layer spec behind
``quantize_params``.

The paper's cost finding is about the *resident working set*: CPU profiles
win exactly when weights + KV fit the cache hierarchy, so the policy's job
is to shrink the big matmul operands while leaving everything whose
precision is load-bearing (or whose size is negligible) alone:

  * ``attn_proj`` — q/k/v (or fused qkv) projections. Contraction over the
    leading ``d_model`` axis; per-(head, head_dim) output channels.
  * ``attn_out``  — the ``wo`` output projection. Contraction over the two
    leading (heads, head_dim) axes; per-``d_model`` output channels.
  * ``mlp``       — gate/up/down projections (fused ``w_in`` included).

Everything else stays in its float dtype: embeddings and the (possibly
tied) lm head (table lookups, and argmax over the vocab is the single most
drift-sensitive op in greedy serving), norms and biases (tiny, and scale
parameters amplify), MoE routers and expert stacks (the router decides
top-k expert assignment — integer noise there reroutes tokens — and the
expert einsums contract a *middle* axis, outside the leading-contraction
layout ``qeinsum`` handles), and all recurrent-state parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# leaf name -> (layer class, number of leading contraction axes). The
# contraction-axis count is what per-channel quantization needs: scales are
# computed per *output* channel, i.e. over every axis after the contraction.
_LEAF_SPECS = {
    "wq": ("attn_proj", 1),
    "wk": ("attn_proj", 1),
    "wv": ("attn_proj", 1),
    "wqkv": ("attn_proj", 1),
    "wo": ("attn_out", 2),
    "w_in": ("mlp", 1),
    "w_up": ("mlp", 1),
    "w_down": ("mlp", 1),
}

# parent keys under which the leaf names above mean what the table says;
# 'mlp' excludes the MoE subtree (parent 'experts'/'shared'), whose einsums
# contract a middle axis and whose routing is precision-sensitive.
_PARENTS = {
    "attn": ("attn_proj", "attn_out"),
    "cross_attn": ("attn_proj", "attn_out"),
    "mlp": ("mlp",),
}

LAYER_CLASSES = ("attn_proj", "attn_out", "mlp")


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-layer quantization spec: which layer classes go int8."""
    classes: frozenset = frozenset(LAYER_CLASSES)

    def n_contract(self, parent: Optional[str], name: str) -> Optional[int]:
        """Leading contraction-axis count for a quantizable leaf at
        ``parent/name``, or None when the leaf stays in float."""
        spec = _LEAF_SPECS.get(name)
        if spec is None or parent is None:
            return None
        cls, nc = spec
        if cls not in self.classes or cls not in _PARENTS.get(parent, ()):
            return None
        return nc


def default_policy() -> QuantPolicy:
    """All three matmul layer classes int8; embeddings/norms/moe stay."""
    return QuantPolicy()
