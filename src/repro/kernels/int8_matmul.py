"""Dequant-fused int8 matmul — quantized weights without a float copy.

Same VMEM-tiled grid as ``cache_matmul`` (one (bm, bk) activation block,
one (bk, bn) weight block and the (bm, bn) fp32 accumulator resident
across the K sweep), but the weight block arrives as int8 and the
per-output-channel scales are applied once, at the accumulator, on the
final K step. int8 values fit bf16/fp32 exactly (|q| <= 127), so casting
the block inside the kernel loses nothing and

    (x @ (q * s_col)) == (x @ q) * s_col

makes the late scale multiply mathematically identical to dequantizing
up front — with the weight operand at half/quarter the HBM traffic and
no materialized dequantized copy anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def vmem_bytes(bm, bn, bk, in_dtype=jnp.bfloat16):
    isz = jnp.dtype(in_dtype).itemsize
    # x block + int8 w block + scale row + fp32 accumulator
    return bm * bk * isz + bk * bn * 1 + bn * 4 + bm * bn * 4


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(x, qw, scale, *, bm=128, bn=128, bk=128, interpret=True):
    """x: (M, K) float @ qw: (K, N) int8, scale: (N,) f32 -> (M, N) x.dtype.

    Scales are broadcast as a (1, bn) block per N tile and applied at the
    fp32 accumulator on the last K step. M/N/K must be divisible by the
    block shape (pad at the ops layer).
    """
    M, K = x.shape
    K2, N = qw.shape
    assert K == K2 and scale.shape == (N,)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, qw, scale.reshape(1, N).astype(jnp.float32))
