"""Single-step GQA decode attention over a (ring-buffered) KV cache.

Grid: (batch*kv_heads, kv_blocks). Each program attends the G grouped query
heads of one kv head against one KV block; running (m, l, acc) state sits in
VMEM scratch across the KV sweep. Validity comes from the cache's absolute
position buffer (pos < 0 = empty slot), so ring-buffer wraparound and
sliding windows fall out of the same mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kvpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, window, softcap, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (G, D)
    k = k_ref[0]                                   # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qpos_ref[0]                            # scalar-ish (1,)
    kv_pos = kvpos_ref[0]                          # (bk,)
    mask = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "bk", "interpret"))
def decode_attention(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
                     bk=128, interpret=True):
    """q: (BHkv, G, D); k/v: (BHkv, L, D); q_pos: (BHkv, 1) int32;
    kv_pos: (BHkv, L) int32 (-1 = empty). L % bk == 0. -> (BHkv, G, D)."""
    BHkv, G, D = q.shape
    L = k.shape[1]
    n_kv = L // bk
    grid = (BHkv, n_kv)
    kern = functools.partial(_kernel, scale=D ** -0.5, window=window,
                             softcap=softcap, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, ki: (bh, ki)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BHkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, q, k, v, kv_pos)
