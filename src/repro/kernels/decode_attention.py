"""Single-step GQA decode attention over a (ring-buffered) KV cache.

Grid: (batch*kv_heads, kv_blocks). Each program attends the G grouped query
heads of one kv head against one KV block; running (m, l, acc) state sits in
VMEM scratch across the KV sweep. Validity comes from the cache's absolute
position buffer (pos < 0 = empty slot), so ring-buffer wraparound and
sliding windows fall out of the same mask.

Block skipping: the validity mask is a cheap (bk,) VPU computation on the
already-resident position block, so it is evaluated *first* and the two
``dot_general``s (the expensive part) run under ``pl.when(any live)``. A
short request in a long cache — the dominant serving shape — then pays for
ceil(len/bk) blocks instead of the full ring sweep, and sliding-window
decode pays O(window) regardless of cache length. Exact: a fully-dead block
contributed p = 0 after masking anyway (see flash_attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kvpos_ref, o_ref, vis_ref,
            m_ref, l_ref, acc_ref, cnt_ref, *, scale, window, softcap, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    # mask first: (bk,) vector ops on the resident position block — if no
    # kv slot in this block is live, skip both dot_generals entirely
    q_pos = qpos_ref[0]                            # scalar-ish (1,)
    kv_pos = kvpos_ref[0]                          # (bk,)
    mask = (kv_pos >= 0) & (kv_pos <= q_pos)
    if window is not None:
        mask &= kv_pos > q_pos - window

    @pl.when(jnp.any(mask))
    def _live():
        q = q_ref[0]                                   # (G, D)
        k = k_ref[0]                                   # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask[None, :], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new
        cnt_ref[...] = cnt_ref[...] + 1

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)
        vis_ref[0, 0] = cnt_ref[0]


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "bk", "interpret", "return_visits"))
def decode_attention(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
                     bk=128, interpret=True, return_visits=False):
    """q: (BHkv, G, D); k/v: (BHkv, L, D); q_pos: (BHkv, 1) int32;
    kv_pos: (BHkv, L) int32 (-1 = empty). L % bk == 0. -> (BHkv, G, D);
    with ``return_visits`` also an int32 (BHkv, 1) count of KV blocks whose
    dot_generals actually ran."""
    BHkv, G, D = q.shape
    L = k.shape[1]
    n_kv = L // bk
    grid = (BHkv, n_kv)
    kern = functools.partial(_kernel, scale=D ** -0.5, window=window,
                             softcap=softcap, n_kv=n_kv)
    out, visits = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, ki: (bh, ki)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHkv, G, D), q.dtype),
            jax.ShapeDtypeStruct((BHkv, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((1,), jnp.int32),          # live-block visit counter
        ],
        interpret=interpret,
    )(q_pos, q, k, v, kv_pos)
    if return_visits:
        return out, visits
    return out
