"""jit'd public wrappers around the Pallas kernels: padding to block
multiples, head reshaping, and CPU/TPU dispatch (interpret=True off-TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cache_matmul import cache_matmul, vmem_bytes  # noqa: F401
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int8_matmul import int8_matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------ quantized matmul
# Which backend ``matmul_q8`` dispatches to. "pallas" runs the dequant-fused
# VMEM-tiled kernel (interpreted off-TPU — parity tests only on CPU);
# "xla" fuses the same late-scale contraction through XLA, which is the
# fast path on CPU hosts (the paper's serving target). Both keep the int8
# weights as the stored operand — neither materializes a float weight copy.
QUANT_MATMUL_IMPL = "xla"


def set_quant_matmul_impl(impl: str) -> str:
    """Switch the quantized-matmul backend; returns the previous value."""
    global QUANT_MATMUL_IMPL
    if impl not in ("xla", "pallas"):
        raise ValueError(f"impl must be 'xla' or 'pallas', got {impl!r}")
    prev = QUANT_MATMUL_IMPL
    QUANT_MATMUL_IMPL = impl
    return prev


# ------------------------------------------------------ attn block sizing
# Measured overrides win over the heuristic; benchmarks/run.py (or a future
# autotuner) populates this via register_attn_block_sizes. Keys bucket the
# sequence lengths to the next power of two so nearby shapes share entries.
_ATTN_BLOCK_TABLE = {}


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _block_key(kind, sq, skv, window):
    return (kind, _pow2_ceil(max(1, sq)), _pow2_ceil(max(1, skv)), window)


def register_attn_block_sizes(kind, sq, skv, window, bq, bk):
    """Record a measured-best (bq, bk) for (kind, shape-bucket, window)."""
    _ATTN_BLOCK_TABLE[_block_key(kind, sq, skv, window)] = (bq, bk)


def attn_block_sizes(kind, sq, skv, *, window=None):
    """(bq, bk) for the attention kernels: autotune table hit if one was
    registered, else a heuristic — blocks shrink to the sequence (less pad
    waste on short serving shapes, floor 16 sublanes) and, for windowed
    attention, bk tightens toward the window so the live KV span stays at
    O(window/bk) blocks after skipping."""
    hit = _ATTN_BLOCK_TABLE.get(_block_key(kind, sq, skv, window))
    if hit is not None:
        return hit
    bq = max(16, min(128, _pow2_ceil(sq)))
    bk = max(16, min(128, _pow2_ceil(skv)))
    if window is not None:
        bk = max(16, min(bk, _pow2_ceil(window)))
    if kind == "decode":
        bq = 1  # single-query sweep; only bk is meaningful
    return bq, bk


def _pad_axis(x, axis, mult, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def matmul(x, w, *, bm=128, bn=128, bk=128):
    """Pad-and-dispatch VMEM-tiled matmul. x: (..., K); w: (K, N)."""
    lead = x.shape[:-1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, x.shape[-1])
    x2 = _pad_axis(_pad_axis(x2, 0, bm), 1, bk)
    w2 = _pad_axis(_pad_axis(w, 0, bk), 1, bn)
    out = cache_matmul(x2, w2, bm=bm, bn=bn, bk=bk,
                       interpret=not _on_tpu())
    return out[:M, : w.shape[1]].reshape(*lead, w.shape[1])


def matmul_q8(x, qw, scale, *, bm=128, bn=128, bk=128):
    """Dequant-fused matmul: x (M, K) float @ qw (K, N) int8 with (N,)
    per-output-channel scales applied at the fp32 accumulator. int8
    magnitudes (<= 127) are exact in bf16, and per-column scales commute
    with the contraction, so both backends equal dequantize-then-matmul
    without ever storing the dequantized weights. Returns (M, N) fp32."""
    if QUANT_MATMUL_IMPL == "xla":
        return jnp.dot(x, qw.astype(x.dtype),
                       preferred_element_type=jnp.float32) * scale
    M, K = x.shape
    N = qw.shape[1]
    x2 = _pad_axis(_pad_axis(x, 0, bm), 1, bk)
    qw2 = _pad_axis(_pad_axis(qw, 0, bk), 1, bn)
    s2 = _pad_axis(scale.astype(jnp.float32), 0, bn)
    out = int8_matmul(x2, qw2, s2, bm=bm, bn=bn, bk=bk,
                      interpret=not _on_tpu())
    return out[:M, :N].astype(jnp.float32)


def mha_prefill(q, k, v, *, causal=True, window=None, softcap=None,
                bq=None, bk=None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D).
    bq/bk default to the autotune/heuristic table (attn_block_sizes)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    hbq, hbk = attn_block_sizes("prefill", Sq, Skv, window=window)
    bq = bq or hbq
    bk = bk or hbk
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    qf = _pad_axis(qf, 1, bq)
    kf = _pad_axis(kf, 1, bk)
    vf = _pad_axis(vf, 1, bk)
    # kv_len masks the padded kv columns inside the kernel — the causal
    # mask alone does not hide them when causal=False
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, kv_len=Skv,
                          interpret=not _on_tpu())
    out = out[:, :Sq].reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    return out


def gqa_decode(q, k, v, q_pos, kv_pos, *, window=None, softcap=None,
               bk=None):
    """q: (B, 1, Hq, D); k/v cache: (B, L, Hkv, D); q_pos: (B,);
    kv_pos: (B, L) -> (B, 1, Hq, D). bk defaults to the heuristic table."""
    B, _, Hq, D = q.shape
    L, Hkv = k.shape[1], k.shape[2]
    bk = bk or attn_block_sizes("decode", 1, L, window=window)[1]
    G = Hq // Hkv
    qf = q[:, 0].reshape(B, Hkv, G, D).reshape(B * Hkv, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, L, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, L, D)
    kf = _pad_axis(kf, 1, bk)
    vf = _pad_axis(vf, 1, bk)
    kvp = jnp.repeat(kv_pos, Hkv, axis=0)                 # (B*Hkv, L)
    kvp = _pad_axis(kvp, 1, bk, value=-1)
    qp = jnp.repeat(q_pos[:, None], Hkv, axis=0).reshape(B * Hkv, 1)
    out = decode_attention(qf, kf, vf, qp, kvp, window=window,
                           softcap=softcap, bk=bk, interpret=not _on_tpu())
    return out.reshape(B, Hkv * G, D)[:, None]


def lru_scan(a, b, *, bs=256):
    """Pad-and-dispatch RG-LRU linear scan. a/b: (B, S, W)."""
    from repro.kernels.rglru_scan import rglru_scan
    S = a.shape[1]
    ap = _pad_axis(a.astype(jnp.float32), 1, bs, value=1.0)  # a=1: identity
    bp = _pad_axis(b.astype(jnp.float32), 1, bs, value=0.0)  # b=0: carry
    out = rglru_scan(ap, bp, bs=bs, interpret=not _on_tpu())
    return out[:, :S]


# Measured attention block sizes from tools/autotune_blocks.py, if the
# sweep has been run; they replace the heuristic entries for their shape
# buckets. Absent file -> heuristics only.
try:
    from repro.kernels.autotuned import MEASURED_ATTN_BLOCKS
except ImportError:  # pragma: no cover - depends on generated file
    MEASURED_ATTN_BLOCKS = {}
for _key, _blocks in MEASURED_ATTN_BLOCKS.items():
    register_attn_block_sizes(*_key, *_blocks)
