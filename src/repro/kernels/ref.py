"""Pure-jnp oracles for every Pallas kernel. Tests assert_allclose the
kernels (interpret=True on CPU) against these across shape/dtype sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def matmul_ref(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def int8_matmul_ref(x, qw, scale):
    """Dequantize-then-matmul oracle for the fused kernel: x (M, K) float,
    qw (K, N) int8, scale (N,) f32 -> (M, N) f32."""
    w = qw.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    return jnp.dot(x.astype(jnp.float32), w)


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """q: (BH, Sq, D); k/v: (BHkv, Skv, D) with BH = BHkv * G (grouped).
    Returns (BH, Sq, D) float32."""
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    G = BH // BHkv
    kx = jnp.repeat(k, G, axis=0)
    vx = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vx.astype(jnp.float32))


def decode_attention_ref(q, k, v, q_pos, kv_pos, *, window=None,
                         softcap=None):
    """q: (BHkv, G, D); k/v: (BHkv, L, D); q_pos: (BHkv,); kv_pos: (BHkv, L)
    (-1 = empty slot). Returns (BHkv, G, D) float32."""
    D = q.shape[-1]
    s = jnp.einsum("bgd,bld->bgl", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        mask &= kv_pos > (q_pos[:, None] - window)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgl,bld->bgd", p, v.astype(jnp.float32))


def rglru_scan_ref(a, b):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.
    a, b: (B, S, W) -> (B, S, W)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
