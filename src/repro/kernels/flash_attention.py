"""Block-wise online-softmax (flash) attention Pallas kernel.

Grid: (batch*q_heads, q_blocks, kv_blocks); the kv dimension is the minor
(sequential) grid axis, so the fp32 (m, l, acc) running state lives in VMEM
scratch across the KV sweep. GQA is zero-copy: the kv BlockSpec index map
divides the head program id by the group size. Supports causal masking,
sliding windows (gemma2 local layers / windowed-global long-context) and
logit softcap.

Block skipping: each q-block only has a *live* KV-block range
[lo(qi), hi(qi)] — causal masking bounds hi (no KV block strictly above the
diagonal contributes), a sliding window bounds lo. Dead blocks used to be
fetched, scored, and masked to NEG_INF; now the kv grid axis is offset by
lo(qi), dead iterations pin their BlockSpec fetch to a live block (no new
data movement) and a ``pl.when`` guard skips both ``dot_general``s. For
causal+windowed attention the kv axis itself shrinks to O(window/bk)
iterations. Skipping is numerically exact: a fully-masked block contributes
p = exp(NEG_INF - m) = 0 and its one-time garbage (before any live block
raised m above NEG_INF) was already wiped by the corr-rescale, so skipped
and masked sweeps produce bit-identical outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _lo_block(qi, *, window, bq, bk):
    """First live kv block for q-block qi (0 when no window)."""
    if window is None:
        return qi * 0
    return jnp.maximum(0, (qi * bq - (window - 1)) // bk)


def _hi_block(qi, *, causal, bq, bk, n_kv):
    """Last live kv block for q-block qi (n_kv-1 when not causal). n_kv
    counts *live* blocks — callers cap it at ceil(kv_len/bk) so fully-pad
    blocks are skipped too."""
    if not causal:
        return qi * 0 + (n_kv - 1)
    return jnp.minimum(n_kv - 1, (qi * bq + bq - 1) // bk)


def n_visited_blocks(*, causal, window, bq, bk, n_kv):
    """Static length of the kv grid axis after skipping. Causal+windowed
    sweeps touch at most ceil((bq + window - 2)/bk) + 1 blocks per q-block;
    everything else keeps the full axis (dead iterations early-out)."""
    if causal and window is not None:
        return min(n_kv, (bq + window - 2) // bk + 2)
    return n_kv


def live_block_counts(sq, skv, *, causal, window, bq, bk, kv_len=None):
    """Reference count of live kv blocks per q-block (host-side oracle for
    the kernel's visit counter). Returns a list of length sq//bq."""
    n_kv = -(-(kv_len or skv) // bk)          # fully-pad blocks are dead
    counts = []
    for qi in range(sq // bq):
        lo = 0 if window is None else max(0, (qi * bq - (window - 1)) // bk)
        hi = n_kv - 1 if not causal else min(n_kv - 1,
                                             (qi * bq + bq - 1) // bk)
        counts.append(max(0, hi - lo + 1))
    return counts


def _kernel(q_ref, k_ref, v_ref, o_ref, vis_ref, m_ref, l_ref, acc_ref,
            cnt_ref, *, scale, causal, window, softcap, bq, bk, n_kv, n_vis,
            kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    lo = _lo_block(qi, window=window, bq=bq, bk=bk)
    hi = _hi_block(qi, causal=causal, bq=bq, bk=bk, n_kv=n_kv)
    ki_eff = lo + ki                     # logical kv block this step scores

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(ki_eff <= hi)
    def _live():
        q = q_ref[0]                                  # (bq, D)
        k = k_ref[0]                                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki_eff * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        if kv_len % bk:            # partial tail block: mask pad columns
            mask &= k_pos < kv_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new
        cnt_ref[...] = cnt_ref[...] + 1

    @pl.when(ki == n_vis - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)
        vis_ref[0, 0] = cnt_ref[0]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret", "return_visits",
    "kv_len"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    bq=128, bk=128, interpret=True, return_visits=False,
                    kv_len=None):
    """q: (BH, Sq, D); k/v: (BHkv, Skv, D), BH = BHkv * G. Sq % bq == 0,
    Skv % bk == 0 (pad at the ops layer). ``kv_len`` (static) is the real
    KV length before padding: pad columns are masked out of the softmax
    (they are NOT hidden by the causal mask when causal=False) and
    fully-pad blocks are skipped. Returns (BH, Sq, D) in q.dtype; with
    ``return_visits`` also an int32 (BH, Sq//bq) count of KV blocks
    actually scored per q-block (the block-skipping audit trail)."""
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    G = BH // BHkv
    kv_len = Skv if kv_len is None else kv_len
    n_kv = -(-kv_len // bk)                   # live blocks only
    n_q = Sq // bq
    n_vis = n_visited_blocks(causal=causal, window=window, bq=bq, bk=bk,
                             n_kv=n_kv)
    grid = (BH, n_q, n_vis)
    kern = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, n_kv=n_kv, n_vis=n_vis,
        kv_len=kv_len)

    def kv_map(bh, qi, ki):
        # offset by the live range start; dead tail iterations re-fetch the
        # last live block (pinned -> no extra data movement) and early-out
        lo = _lo_block(qi, window=window, bq=bq, bk=bk)
        hi = _hi_block(qi, causal=causal, bq=bq, bk=bk, n_kv=n_kv)
        return (bh // G, jnp.minimum(lo + ki, hi), 0)

    out, visits = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, 1), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((BH, n_q), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
            pltpu.VMEM((1,), jnp.int32),          # live-block visit counter
        ],
        interpret=interpret,
    )(q, k, v)
    if return_visits:
        return out, visits
    return out
