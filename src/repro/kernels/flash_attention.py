"""Block-wise online-softmax (flash) attention Pallas kernel.

Grid: (batch*q_heads, q_blocks, kv_blocks); the kv dimension is the minor
(sequential) grid axis, so the fp32 (m, l, acc) running state lives in VMEM
scratch across the KV sweep. GQA is zero-copy: the kv BlockSpec index map
divides the head program id by the group size. Supports causal masking,
sliding windows (gemma2 local layers / windowed-global long-context) and
logit softcap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, bq, bk, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (bq, D)
    k = k_ref[0]                                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    bq=128, bk=128, interpret=True):
    """q: (BH, Sq, D); k/v: (BHkv, Skv, D), BH = BHkv * G. Sq % bq == 0,
    Skv % bk == 0 (pad at the ops layer). Returns (BH, Sq, D) in q.dtype."""
    BH, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    G = BH // BHkv
    n_kv = Skv // bk
    grid = (BH, Sq // bq, n_kv)
    kern = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
