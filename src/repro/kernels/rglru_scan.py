"""RG-LRU linear-scan Pallas kernel: h_t = a_t * h_{t-1} + b_t.

Griffin implements this recurrence as a fused sequential CUDA kernel; the
TPU adaptation streams (seq_block x width) tiles through VMEM with the
carried state h held in VMEM scratch across the sequential seq-block grid
dimension — the within-tile loop is over rows (time), vectorized across the
width lanes (W is a multiple of 128 for every assigned config).

Used for decode/long-context serving of recurrentgemma; training/prefill
use the XLA `associative_scan` path (log-depth, better for long S on the
MXU-free part of the chip) — both are validated against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bs):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                                    # (bs, W)
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, bs, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def rglru_scan(a, b, *, bs=256, interpret=True):
    """a, b: (B, S, W) float32, S % bs == 0 -> h: (B, S, W)."""
    B, S, W = a.shape
    assert S % bs == 0
    grid = (B, S // bs)
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, W), lambda bi, si: (bi, si, 0)),
            pl.BlockSpec((1, bs, W), lambda bi, si: (bi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, W), lambda bi, si: (bi, si, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((W,), jnp.float32)],  # carried state
        interpret=interpret,
    )(a, b)
