"""VMEM-tiled matmul — the paper's cache-locality finding as a TPU kernel.

The paper's headline CPU result is that the machine whose working set fits
processor cache (SRAM) beats machines with 2x the vCPUs ("machine C vs E",
>50 % cost reduction). On TPU the same SRAM-vs-DRAM cliff is VMEM vs HBM.
This kernel tiles C = A @ B so that one (bm x bk), (bk x bn) and the
(bm x bn) fp32 accumulator stay VMEM-resident across the K sweep; block
shapes default to MXU-aligned multiples of 128 and are validated against the
~16 MiB VMEM budget by ``vmem_bytes``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def vmem_bytes(bm, bn, bk, in_dtype=jnp.bfloat16):
    isz = jnp.dtype(in_dtype).itemsize
    return bm * bk * isz + bk * bn * isz + bm * bn * 4  # fp32 accumulator


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def cache_matmul(x, w, *, bm=128, bn=128, bk=128, interpret=True):
    """x: (M, K) @ w: (K, N) -> (M, N) in x.dtype, fp32 accumulation.

    M/N/K must be divisible by the block shape (pad at the ops layer).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        # fp32 accumulator lives in VMEM across the K sweep
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
