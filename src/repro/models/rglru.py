"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t) is linear in
h, so the whole sequence is computed with ``jax.lax.associative_scan`` — the
TPU-native parallel-scan mapping of the paper's GPU "linear scan" kernel
(this is the hardware adaptation: log-depth scan over the sequence instead of
a fused sequential CUDA kernel). Decode is a single fused step.

Block layout (one "recurrent block" of Griffin):
  norm -> [branch x: linear -> causal conv4 -> RG-LRU] * [branch g: linear
  -> GeLU] -> linear out.  Gate projections are per-head block-diagonal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, norm_init, split_keys
from repro.parallel.sharding import shard_activation

_C = 8.0  # Griffin's fixed gate sharpness constant


def rglru_init(cfg, rng):
    d = cfg.d_model
    w = cfg.rglru_rnn_width or d
    nh = cfg.n_heads
    bw = w // nh
    ks = split_keys(rng, 8)
    return {
        "norm": norm_init(cfg),
        "w_x": dense_init(ks[0], (d, w), d, cfg.jdtype),
        "w_gate": dense_init(ks[1], (d, w), d, cfg.jdtype),
        "conv_w": dense_init(ks[2], (4, w), 4, jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        # block-diagonal (per-head) input and recurrence gates
        "gate_x": {"w": dense_init(ks[3], (nh, bw, bw), bw, jnp.float32),
                   "b": jnp.zeros((nh, bw), jnp.float32)},
        "gate_a": {"w": dense_init(ks[4], (nh, bw, bw), bw, jnp.float32),
                   "b": jnp.zeros((nh, bw), jnp.float32)},
        # a_param init so that a ~ U(0.9, 0.999) at r=1 (Griffin init)
        "a_param": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "w_out": dense_init(ks[5], (w, d), w, cfg.jdtype),
    }


def rglru_state(cfg, batch):
    w = cfg.rglru_rnn_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, 3, w), jnp.float32)}


def _gates(p, xb):
    """xb: (..., w) float32 -> (a, gated_input) with per-head block-diag."""
    nh, bw = p["gate_x"]["w"].shape[0], p["gate_x"]["w"].shape[1]
    xh = xb.reshape(*xb.shape[:-1], nh, bw)
    rt = jax.nn.sigmoid(
        jnp.einsum("...hk,hkv->...hv", xh, p["gate_a"]["w"]) + p["gate_a"]["b"])
    it = jax.nn.sigmoid(
        jnp.einsum("...hk,hkv->...hv", xh, p["gate_x"]["w"]) + p["gate_x"]["b"])
    rt = rt.reshape(xb.shape)
    it = it.reshape(xb.shape)
    log_a = -_C * jax.nn.softplus(p["a_param"]) * rt
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * (it * xb)


def _causal_conv4(p, x, conv_state=None):
    """Depthwise causal conv, width 4. x: (B,S,w) f32."""
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], 3, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+3, w)
    out = sum(xp[:, 3 - i: xp.shape[1] - i] * p["conv_w"][3 - i]
              for i in range(4)) + p["conv_b"]
    new_state = xp[:, -3:]
    return out, new_state


def rglru_apply(cfg, p, x, state=None):
    """x: (B, S, d) -> (delta, state)."""
    B, S, _ = x.shape
    from repro.models.layers import apply_norm
    xn = apply_norm(cfg, p["norm"], x)
    xb = jnp.einsum("bsd,dw->bsw", xn, p["w_x"]).astype(jnp.float32)
    xb = shard_activation(xb, "batch", None, "model")
    gb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, p["w_gate"]))
    gb = shard_activation(gb, "batch", None, "model")

    conv_state = state["conv"] if state is not None else None
    xb, new_conv = _causal_conv4(p, xb, conv_state)

    a, b = _gates(p, xb)                                    # (B,S,w) each
    if state is not None:
        # fold carried h into the first step: h_0' contribution
        b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    new_state = {"h": h[:, -1], "conv": new_conv}

    y = (h.astype(x.dtype)) * gb.astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    from repro.models.runtime_flags import residual_axes
    return shard_activation(out, *residual_axes()), new_state


def rglru_step(cfg, p, x, state):
    """Single decode step. x: (B, 1, d)."""
    from repro.models.layers import apply_norm
    xn = apply_norm(cfg, p["norm"], x)
    xb = jnp.einsum("bsd,dw->bsw", xn, p["w_x"]).astype(jnp.float32)
    gb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, p["w_gate"]))
    xb, new_conv = _causal_conv4(p, xb, state["conv"])
    a, b = _gates(p, xb)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = h[:, None].astype(x.dtype) * gb.astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return (shard_activation(out, "batch", None, None),
            {"h": h, "conv": new_conv})
