"""Shared layers: norms, rotary embeddings, embeddings, MLPs, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_activation
from repro.quant.weights import qeinsum


# ---------------------------------------------------------------- init utils
def dense_init(rng, shape, in_axis_dims, dtype):
    """Truncated-normal fan-in init (as used by most of the assigned models)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(float(in_axis_dims)))
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def split_keys(rng, n):
    return list(jax.random.split(rng, n))


# --------------------------------------------------------------------- norms
def norm_init(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm (gemma-style 1+scale kept simple: plain scale)
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope_freqs(head_dim, base):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (base ** exponent)                       # (head_dim/2,)


def apply_rope(x, positions, base):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, base)                           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embed_init(cfg, rng):
    return {"table": dense_init(rng, (cfg.padded_vocab, cfg.d_model),
                                cfg.d_model, jnp.float32)}


def embed_apply(cfg, p, tokens):
    out = jnp.take(p["table"].astype(cfg.jdtype), tokens, axis=0)
    return shard_activation(out, "batch", None, None)


def pos_embed_init(cfg, rng, max_len):
    return {"table": dense_init(rng, (max_len, cfg.d_model), cfg.d_model,
                                jnp.float32)}


def lm_head_init(cfg, rng):
    return {"w": dense_init(rng, (cfg.d_model, cfg.padded_vocab), cfg.d_model,
                            cfg.jdtype)}


def lm_head_apply(cfg, params, x, embed_params=None):
    if cfg.tie_embeddings:
        w = embed_params["table"].astype(cfg.jdtype).T
    else:
        w = params["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = shard_activation(logits, "batch", None, "model")
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c)
    else:
        logits = logits.astype(jnp.float32)
    # mask padded vocab entries
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.finfo(jnp.float32).min
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, neg)
    return logits


# ----------------------------------------------------------------------- mlp
def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp_init(cfg, rng, d_ff=None, d_in=None):
    d_in = d_in or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    ks = split_keys(rng, 3)
    if cfg.gated_mlp:
        # fused gate|up layout (d, 2, F): one column-parallel dot -> one
        # backward dx all-reduce instead of two (§Perf iteration B2); the
        # gate/up split indexes the unsharded middle dim, so it stays local
        return {"w_in": dense_init(ks[0], (d_in, 2, d_ff), d_in, cfg.jdtype),
                "w_down": dense_init(ks[1], (d_ff, d_in), d_ff, cfg.jdtype)}
    return {"w_up": dense_init(ks[0], (d_in, d_ff), d_in, cfg.jdtype),
            "w_down": dense_init(ks[1], (d_ff, d_in), d_ff, cfg.jdtype)}


def mlp_apply(cfg, p, x):
    if cfg.gated_mlp:
        gu = qeinsum("bsd,dcf->bscf", x, p["w_in"])
        gu = shard_activation(gu, "batch", None, None, "model")
        h = act_fn(cfg.act)(gu[:, :, 0]) * gu[:, :, 1]
    else:
        h = act_fn(cfg.act)(qeinsum("bsd,df->bsf", x, p["w_up"]))
    h = shard_activation(h, "batch", None, "model")
    out = qeinsum("bsf,fd->bsd", h, p["w_down"])
    from repro.models.runtime_flags import residual_axes
    return shard_activation(out, *residual_axes())
