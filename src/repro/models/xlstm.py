"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, exp gating) and
sLSTM (scalar memory, exp gating with stabilizer state).

Both are implemented in their recurrent form via ``jax.lax.scan`` over time
(the HLO contains the loop body once, so deep/long configs lower cheaply) and
expose single-step functions for serving. State, not KV cache, is the decode
artifact — this is what makes xlstm-125m admissible at long_500k.

Simplifications vs the reference implementation (recorded in DESIGN.md):
the pre-QKV causal conv4 of the mLSTM block is omitted; GroupNorm after the
cell is RMSNorm over the concatenated heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_norm, dense_init, norm_init, split_keys
from repro.parallel.sharding import shard_activation


# ===================================================================== mLSTM
def mlstm_init(cfg, rng):
    d = cfg.d_model
    dp = int(d * cfg.xlstm_proj_factor)
    nh = cfg.n_heads
    hd = dp // nh
    ks = split_keys(rng, 8)
    return {
        "norm": norm_init(cfg),
        "w_in": dense_init(ks[0], (d, 2 * dp), d, cfg.jdtype),
        "wq": dense_init(ks[1], (dp, nh, hd), dp, cfg.jdtype),
        "wk": dense_init(ks[2], (dp, nh, hd), dp, cfg.jdtype),
        "wv": dense_init(ks[3], (dp, nh, hd), dp, cfg.jdtype),
        "w_igate": dense_init(ks[4], (dp, nh), dp, jnp.float32),
        "w_fgate": dense_init(ks[5], (dp, nh), dp, jnp.float32),
        "b_igate": jnp.zeros((nh,), jnp.float32),
        "b_fgate": jnp.full((nh,), 3.0, jnp.float32),  # forget-open init
        "cell_norm": norm_init(cfg, dp),
        "w_out": dense_init(ks[6], (dp, d), dp, cfg.jdtype),
    }


def mlstm_state(cfg, batch):
    dp = int(cfg.d_model * cfg.xlstm_proj_factor)
    nh = cfg.n_heads
    hd = dp // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        # -inf stabilizer start: the first step then has i-weight 1 and no
        # history decay, which is exactly the parallel form's convention
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def _mlstm_cell(q, k, v, it, ft, state):
    """One step. q/k/v: (B, nh, hd); it/ft: (B, nh) raw gate pre-acts."""
    hd = q.shape[-1]
    m_new = jnp.maximum(ft + state["m"], it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + state["m"] - m_new)
    kf = k.astype(jnp.float32) / jnp.sqrt(float(hd))
    C = (f[..., None, None] * state["C"]
         + i[..., None, None] * (v.astype(jnp.float32)[..., :, None]
                                 * kf[..., None, :]))
    n = f[..., None] * state["n"] + i[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return h, {"C": C, "n": n, "m": m_new}


def _mlstm_parallel(q, k, v, it, ft, *, q_chunk=256, kv_chunk=256):
    """Chunkwise-parallel mLSTM — the TPU adaptation of the matrix-memory
    recurrence (DESIGN.md §hardware-adaptation).

    Unrolling the stabilized recurrence gives exactly decay-masked attention:
        m_t   = max_{j<=t} (F_t - F_j + i_j)        (max-plus assoc. scan)
        s_tj  = (q_t . k_j / sqrt(d)) * exp(F_t - F_j + i_j - m_t),  j <= t
        h_t   = sum_j s_tj v_j / max(|sum_j s_tj|, 1)
    with F = cumsum(log f). All exponents are <= 0 by construction of m, so
    the tiled evaluation is numerically stable. Training/prefill runs this
    parallel form (the sequential scan would put the (B,H,D,D) matrix state
    into AD residuals at every step — terabytes at 4k); decode keeps the
    recurrent cell.

    q/k/v: (B, S, H, D); it/ft: (B, S, H) (ft already log-sigmoid).
    Returns (B, S, H, D) float32, and the final (C, n, m) state.
    """
    B, S, H, D = q.shape
    kf = k.astype(jnp.float32) / jnp.sqrt(float(D))
    F = jnp.cumsum(ft, axis=1)                               # (B, S, H)

    def mx(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 + a2, jnp.maximum(b1 + a2, b2)

    _, m = jax.lax.associative_scan(mx, (ft, it), axis=1)    # (B, S, H)

    q = _pad_seq(q, q_chunk)
    Fq = _pad_seq(F, q_chunk)
    mq = _pad_seq(m, q_chunk)
    kfp = _pad_seq(kf, kv_chunk)
    vp = _pad_seq(v, kv_chunk)
    Fk = _pad_seq(F, kv_chunk)
    ik = _pad_seq(it, kv_chunk, value=-1e30)
    nq, nk = q.shape[1] // q_chunk, kfp.shape[1] // kv_chunk

    def q_block(qi):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 1)
        Fc = jax.lax.dynamic_slice_in_dim(Fq, qi * q_chunk, q_chunk, 1)
        mc = jax.lax.dynamic_slice_in_dim(mq, qi * q_chunk, q_chunk, 1)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            num, den = carry
            kc = jax.lax.dynamic_slice_in_dim(kfp, ki * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(vp, ki * kv_chunk, kv_chunk, 1)
            Fj = jax.lax.dynamic_slice_in_dim(Fk, ki * kv_chunk, kv_chunk, 1)
            ij = jax.lax.dynamic_slice_in_dim(ik, ki * kv_chunk, kv_chunk, 1)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32), kc)
            expo = (Fc.transpose(0, 2, 1)[:, :, :, None]
                    - Fj.transpose(0, 2, 1)[:, :, None, :]
                    + ij.transpose(0, 2, 1)[:, :, None, :]
                    - mc.transpose(0, 2, 1)[:, :, :, None])
            causal = kpos[None, :] <= qpos[:, None]
            w = jnp.where(causal[None, None],
                          jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
            sw = s * w
            num = num + jnp.einsum("bhqk,bkhd->bhqd", sw,
                                   vc.astype(jnp.float32))
            den = den + jnp.sum(sw, axis=-1)
            return (num, den), None

        num0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        den0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (num, den), _ = jax.lax.scan(kv_step, (num0, den0), jnp.arange(nk))
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        return h.transpose(0, 2, 1, 3)                       # (B, C, H, D)

    q_block = jax.checkpoint(q_block)
    hs = jax.lax.map(q_block, jnp.arange(nq))                # (nq,B,C,H,D)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, D)[:, :S]

    # final state for decode continuation (exact recurrent state at t=S)
    m_last = m[:, -1]                                        # (B, H)
    decay = jnp.exp(jnp.minimum(F[:, -1][:, :, None] - F.transpose(0, 2, 1)
                                + it.transpose(0, 2, 1)
                                - m_last[:, :, None], 0.0))  # (B,H,S)
    C = jnp.einsum("bhs,bshv,bshk->bhvk", decay, v.astype(jnp.float32), kf)
    n = jnp.einsum("bhs,bshk->bhk", decay, kf)
    state = {"C": C, "n": n, "m": m_last}
    return hs, state


def _pad_seq(x, mult, value=0.0):
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def mlstm_apply(cfg, p, x, state=None):
    """x: (B, S, d). Returns (out, final_state)."""
    B, S, d = x.shape
    xn = apply_norm(cfg, p["norm"], x)
    proj = jnp.einsum("bsd,de->bse", xn, p["w_in"])
    proj = shard_activation(proj, "batch", None, "model")
    main, gate = jnp.split(proj, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", main, p["wq"])
    k = jnp.einsum("bse,ehk->bshk", main, p["wk"])
    v = jnp.einsum("bse,ehk->bshk", main, p["wv"])
    it = jnp.einsum("bse,eh->bsh", main.astype(jnp.float32), p["w_igate"]) \
        + p["b_igate"]
    ft = jnp.einsum("bse,eh->bsh", main.astype(jnp.float32), p["w_fgate"]) \
        + p["b_fgate"]
    ft = jax.nn.log_sigmoid(ft)

    if state is None and S > 1:
        # chunkwise-parallel form (training / from-scratch prefill)
        from repro.models import runtime_flags
        if runtime_flags.COST_MODE:      # loop-free for cost_analysis
            hs, state = _mlstm_parallel(q, k, v, it, ft,
                                        q_chunk=S, kv_chunk=S)
        else:
            hs, state = _mlstm_parallel(q, k, v, it, ft)
        hs = hs.reshape(B, S, -1)
    else:
        if state is None:
            state = mlstm_state(cfg, B)

        def step(st, inp):
            qt, kt, vt, i_t, f_t = inp
            h, st = _mlstm_cell(qt, kt, vt, i_t, f_t, st)
            return st, h

        state, hs = jax.lax.scan(
            step, state,
            (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
             v.transpose(1, 0, 2, 3), it.transpose(1, 0, 2),
             ft.transpose(1, 0, 2)))
        hs = hs.transpose(1, 0, 2, 3).reshape(B, S, -1)      # (B,S,dp)
    hs = apply_norm(cfg, p["cell_norm"], hs.astype(x.dtype))
    hs = hs * jax.nn.silu(gate)
    out = jnp.einsum("bse,ed->bsd", hs, p["w_out"])
    from repro.models.runtime_flags import residual_axes
    return shard_activation(out, *residual_axes()), state


# ===================================================================== sLSTM
def slstm_init(cfg, rng):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ks = split_keys(rng, 12)
    p = {"norm": norm_init(cfg)}
    for g, kw, kr in zip("izfo", ks[0:4], ks[4:8]):
        p[f"w_{g}"] = dense_init(kw, (d, nh, hd), d, cfg.jdtype)
        p[f"r_{g}"] = dense_init(kr, (nh, hd, hd), hd, cfg.jdtype)
        p[f"b_{g}"] = jnp.zeros((nh, hd), jnp.float32)
    p["cell_norm"] = norm_init(cfg)
    ff = int(d * 4 / 3)
    p["ffn"] = {
        "norm": norm_init(cfg),
        "w_gate": dense_init(ks[8], (d, ff), d, cfg.jdtype),
        "w_up": dense_init(ks[9], (d, ff), d, cfg.jdtype),
        "w_down": dense_init(ks[10], (ff, d), ff, cfg.jdtype),
    }
    return p


def slstm_state(cfg, batch):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((batch, nh, hd),
                                                   jnp.float32)}


def _slstm_cell(p, wx, state):
    """wx: dict gate -> (B, nh, hd) input contributions."""
    h_prev = state["h"]
    pre = {g: wx[g]
           + jnp.einsum("bhk,hkv->bhv", h_prev, p[f"r_{g}"].astype(jnp.float32))
           + p[f"b_{g}"] for g in "izfo"}
    zt = jnp.tanh(pre["z"])
    ot = jax.nn.sigmoid(pre["o"])
    logf = jax.nn.log_sigmoid(pre["f"])
    m_new = jnp.maximum(logf + state["m"], pre["i"])
    i = jnp.exp(pre["i"] - m_new)
    f = jnp.exp(logf + state["m"] - m_new)
    c = f * state["c"] + i * zt
    n = f * state["n"] + i
    h = ot * c / jnp.maximum(n, 1.0)
    return h, {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(cfg, p, x, state=None):
    B, S, d = x.shape
    nh = cfg.n_heads
    xn = apply_norm(cfg, p["norm"], x).astype(jnp.float32)
    wx = {g: jnp.einsum("bsd,dhk->bshk", xn, p[f"w_{g}"].astype(jnp.float32))
          for g in "izfo"}
    if state is None:
        state = slstm_state(cfg, B)

    def step(st, inp):
        h, st = _slstm_cell(p, dict(zip("izfo", inp)), st)
        return st, h

    state, hs = jax.lax.scan(
        step, state, tuple(wx[g].transpose(1, 0, 2, 3) for g in "izfo"))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    out = apply_norm(cfg, p["cell_norm"], hs)
    # post-FFN sub-block (proj factor 4/3, gated)
    y = x + out
    yn = apply_norm(cfg, p["ffn"]["norm"], y)
    g = jnp.einsum("bsd,df->bsf", yn, p["ffn"]["w_gate"])
    u = jnp.einsum("bsd,df->bsf", yn, p["ffn"]["w_up"])
    hmid = jax.nn.gelu(g) * u
    hmid = shard_activation(hmid, "batch", None, "model")
    ffn_out = jnp.einsum("bsf,fd->bsd", hmid, p["ffn"]["w_down"])
    # returns the *delta* to add to the residual stream: out + ffn path
    total = out + ffn_out
    from repro.models.runtime_flags import residual_axes
    return shard_activation(total, *residual_axes()), state
