"""Global execution-mode switches.

COST_MODE: used only by the dry-run's *cost-measurement* compiles. XLA's
``cost_analysis`` counts a while-loop body once regardless of trip count, so
inner loops (chunked attention / chunked CE / chunkwise mLSTM) would make
FLOPs/collective counts meaningless. In cost mode every inner loop collapses
to a single iteration (naive attention, full-width CE): the lowered module
then has loop-free layer bodies, and the dry-run recovers full-model costs
by depth-differencing two shallow variants (see launch/dryrun.py). Memory
analysis always comes from the real (chunked, full-depth) compile.
"""
COST_MODE = False

# Megatron-style sequence parallelism for the residual stream during
# training: block outputs are annotated seq-sharded over the model axis so
# GSPMD emits reduce-scatter (half the bytes of all-reduce + no separate
# re-shard) — §Perf iteration B. Set by models.transformer.forward while
# tracing a seq_shard=True step; tracing is single-threaded per call.
SEQ_SHARD = False


def set_cost_mode(v: bool) -> None:
    global COST_MODE
    COST_MODE = v


def residual_axes():
    """Activation axes for (B, S, D) block outputs on the residual stream."""
    return ("batch", "model" if SEQ_SHARD else None, None)
