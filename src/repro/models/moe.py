"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU-native design notes (hardware adaptation):
  * dispatch is the GShard/MaxText sort-permute pattern, not a torch-style
    per-expert loop: tokens are argsorted by expert id, placed into a dense
    (E, C, D) buffer (C = capacity), processed with one batched einsum
    ``ecd,edf->ecf`` that maps straight onto the MXU, and scattered back.
  * expert weights are sharded over the ``model`` mesh axis — on the expert
    dim when E divides the axis ('expert' mode → all-to-all dispatch), else
    on each expert's d_ff ('tensor' mode, e.g. qwen2-moe's 60 experts on a
    16-way axis). The mode is chosen by ``parallel.sharding.rules_for``.
  * the router aux (load-balance) loss and router-z loss are returned so the
    trainer can add them (Switch-Transformer style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn, dense_init, split_keys
from repro.parallel.sharding import current_rules, shard_activation


def moe_init(cfg, rng):
    m = cfg.moe
    d = cfg.d_model
    e_ff = m.expert_d_ff or cfg.d_ff
    s_ff = m.shared_d_ff or e_ff
    ks = split_keys(rng, 8)
    experts = {
        # fused gate|up (E, D, 2, F): one expert einsum for the up path ->
        # one backward all-reduce of the dispatch buffer (§Perf iter. B2/C)
        "w_in": dense_init(ks[0], (m.num_experts, d, 2, e_ff), d, cfg.jdtype),
        "w_down": dense_init(ks[2], (m.num_experts, e_ff, d), e_ff, cfg.jdtype),
    }
    p = {"router": dense_init(ks[3], (d, m.num_experts), d, jnp.float32),
         "experts": experts}
    if m.num_shared_experts:
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, s_ff * m.num_shared_experts), d,
                                 cfg.jdtype),
            "w_up": dense_init(ks[5], (d, s_ff * m.num_shared_experts), d,
                               cfg.jdtype),
            "w_down": dense_init(ks[6], (s_ff * m.num_shared_experts, d),
                                 s_ff, cfg.jdtype),
        }
    return p


def _expert_spec_axes():
    rules = current_rules()
    if rules is None:
        return (None, None, None)
    if rules.expert_mode == "expert":
        return ("model", None, None)
    return (None, None, "model")


def moe_apply(cfg, p, x, *, capacity_factor: float = None):
    """x: (B, S, D) -> (out, aux) with aux = dict(load_balance_loss, router_z).

    Dispatch is *per batch row*: each row sorts its own S*K (token, expert)
    copies into an (E, C_row, D) buffer. Because the row dim stays sharded
    over the data axes, the sort/scatter is shard-local; the only cross-
    device traffic is the expert einsum against model-axis-sharded expert
    weights (the all-to-all of classic expert parallelism, inserted by
    GSPMD). A single global (E, C, D) buffer would force GSPMD to
    replicate ~N*K*cf*D activations per device — measured at 21 GB/device
    for qwen2-moe train_4k — hence the hierarchical layout.
    """
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    NK = S * K

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_ids = jax.lax.top_k(probs, K)            # (B, S, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch style) ----
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(expert_ids, E).sum(2) > 0).astype(jnp.float32),
        (0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = {
        "load_balance_loss": E * jnp.sum(frac_tokens * frac_probs),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }

    # ---- per-row sort-based dispatch into (B, E, C, D) ----
    C = max(int(S * K * capacity_factor / E), 4)
    flat_e = expert_ids.reshape(B, NK)                      # (B, NK)
    tok_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), K)[None], (B, NK))
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sorted_tok = jnp.take_along_axis(tok_of, order, axis=1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (B, NK, E)
    group_sizes = onehot.sum(1)                             # (B, E)
    group_start = jnp.cumsum(group_sizes, 1) - group_sizes
    pos_in_group = (jnp.arange(NK)[None]
                    - jnp.take_along_axis(group_start, sorted_e, axis=1))
    keep = pos_in_group < C
    slot = jnp.where(keep, pos_in_group, C)                 # C = trash slot

    # flat-index scatter/gather via *_along_axis: integer fancy indexing
    # materializes (B, NK, D)-broadcast u32 index tensors that GSPMD then
    # all-gathers (192 GiB/device on moonshot train_4k — §Perf iteration C2)
    xg = jnp.take_along_axis(x, sorted_tok[..., None], axis=1)   # (B,NK,D)
    flat_slot = sorted_e * (C + 1) + slot                        # (B, NK)

    def _row_dispatch(xr, slots):
        z = jnp.zeros((E * (C + 1), D), x.dtype)
        return z.at[slots].set(xr, mode="drop")

    buf = jax.vmap(_row_dispatch)(xg, flat_slot)
    buf = buf.reshape(B, E, C + 1, D)[:, :, :C]
    ax = _expert_spec_axes()
    # keep the dispatch buffer REPLICATED on E: the scatter above is then
    # shard-local (scattering into an E-sharded buffer made GSPMD fully
    # rematerialize it — ~1 TB/device/layer of collectives on moonshot
    # train_4k, §Perf iteration C); the expert einsum below slices the
    # replicated buffer against E-sharded weights for free
    buf = shard_activation(buf, "batch", None, None, None)

    # ---- batched expert MLP: (B,E,C,D) x (E,D,2,F) fused gate|up ----
    gu = jnp.einsum("becd,edxf->bexcf", buf, p["experts"]["w_in"])
    gu = shard_activation(gu, "batch", ax[0], None, None, ax[2])
    h = act_fn(cfg.act)(gu[:, :, 0]) * gu[:, :, 1]
    h = shard_activation(h, "batch", ax[0], None, ax[2])
    y = jnp.einsum("becf,efd->becd", h, p["experts"]["w_down"])
    # one all-gather of the (small) expert outputs; the combine gather below
    # is then shard-local
    y = shard_activation(y, "batch", None, None, None)

    # ---- combine back: weighted scatter-add into (B, S, D) ----
    ypad = jnp.concatenate([y, jnp.zeros((B, E, 1, D), y.dtype)],
                           axis=2).reshape(B, E * (C + 1), D)
    gathered = jnp.take_along_axis(ypad, flat_slot[..., None], axis=1)
    w_sorted = (jnp.take_along_axis(gate_w.reshape(B, NK), order, axis=1)
                * keep)
    contrib = gathered.astype(jnp.float32) * w_sorted[..., None]

    def _row_combine(c, toks):
        return jnp.zeros((S, D), jnp.float32).at[toks].add(c)

    out = jax.vmap(_row_combine)(contrib, sorted_tok).astype(x.dtype)

    if m.num_shared_experts:
        sg = jnp.einsum("bsd,df->bsf", x, p["shared"]["w_gate"])
        su = jnp.einsum("bsd,df->bsf", x, p["shared"]["w_up"])
        sh = act_fn(cfg.act)(sg) * su
        sh = shard_activation(sh, "batch", None, "model")
        # shared experts are fused along the d_ff axis of a single MLP
        out = out + jnp.einsum("bsf,fd->bsd", sh, p["shared"]["w_down"])

    from repro.models.runtime_flags import residual_axes
    return shard_activation(out, *residual_axes()), aux
