from repro.models.config import (AttnConfig, ModelConfig, MoEConfig,  # noqa
                                 ShapeConfig, SHAPES)
from repro.models.transformer import (decode_step, forward, init_params,  # noqa
                                      make_caches, prefill)
