from repro.models.config import (AttnConfig, ModelConfig, MoEConfig,  # noqa
                                 ShapeConfig, SHAPES)
from repro.models.transformer import (decode_loop, decode_segment,  # noqa
                                      decode_step, forward, init_params,
                                      make_caches, prefill, prefill_chunk,
                                      sample_logits, spec_round,
                                      verify_chunk)
