from repro.models.config import (AttnConfig, ModelConfig, MoEConfig,  # noqa
                                 ShapeConfig, SHAPES)
from repro.models.transformer import (decode_loop, decode_step, forward,  # noqa
                                      init_params, make_caches, prefill)
