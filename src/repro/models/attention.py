"""Grouped-query attention with sliding-window / softcap variants.

Two execution paths:
  * ``chunked_attention`` — pure-jnp online-softmax attention computed in
    (q_chunk × kv_chunk) tiles under ``jax.checkpoint``. This is the XLA path
    used for training/prefill; it is the same algorithm as the Pallas
    ``flash_attention`` kernel (kernels/flash_attention.py) and keeps peak
    memory at tile size, which is what makes prefill_32k fit HBM.
  * decode: single-query attention over a (possibly ring-buffered) KV cache.

The Pallas kernels are the TPU hot path and are validated against these
reference implementations in tests; the XLA path is used for lowering /
cost-analysis because a Pallas custom-call is opaque to ``cost_analysis()``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, split_keys
from repro.parallel.sharding import shard_activation
from repro.quant.kv import dequantize_kv, quantize_kv
from repro.quant.weights import qeinsum

NEG_INF = -1e30


# ----------------------------------------------------------------- params
def attn_init(cfg, rng, d_model=None):
    d = d_model or cfg.d_model
    hd, hq, hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    ks = split_keys(rng, 4)
    if cfg.fused_qkv:
        # grouped layout: per kv-group [q_0..q_{gq-1}, k, v] — one dot, and
        # the split into q/k/v is local under group (model-axis) sharding
        gq = hq // hkv
        p = {
            "wqkv": dense_init(ks[0], (d, hkv, gq + 2, hd), d, cfg.jdtype),
            "wo": dense_init(ks[3], (hq, hd, d), hq * hd, cfg.jdtype),
        }
        if cfg.attn.qkv_bias:
            p["bqkv"] = jnp.zeros((hkv, gq + 2, hd), cfg.jdtype)
        return p
    p = {
        "wq": dense_init(ks[0], (d, hq, hd), d, cfg.jdtype),
        "wk": dense_init(ks[1], (d, hkv, hd), d, cfg.jdtype),
        "wv": dense_init(ks[2], (d, hkv, hd), d, cfg.jdtype),
        "wo": dense_init(ks[3], (hq, hd, d), hq * hd, cfg.jdtype),
    }
    if cfg.attn.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), cfg.jdtype)
        p["bk"] = jnp.zeros((hkv, hd), cfg.jdtype)
        p["bv"] = jnp.zeros((hkv, hd), cfg.jdtype)
    return p


def _project_qkv(cfg, p, x, positions):
    if "wqkv" in p:
        B, S = x.shape[:2]
        hkv = cfg.n_kv_heads
        gq = cfg.n_heads // hkv
        qkv = qeinsum("bsd,dgch->bsgch", x, p["wqkv"])
        if cfg.attn.qkv_bias:
            qkv = qkv + p["bqkv"]
        qkv = shard_activation(qkv, "batch", None, "model", None, None)
        q = qkv[:, :, :, :gq].reshape(B, S, cfg.n_heads, cfg.head_dim_)
        k = qkv[:, :, :, gq]
        v = qkv[:, :, :, gq + 1]
    else:
        q = qeinsum("bsd,dhk->bshk", x, p["wq"])
        k = qeinsum("bsd,dhk->bshk", x, p["wk"])
        v = qeinsum("bsd,dhk->bshk", x, p["wv"])
        if cfg.attn.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.attn.rope_base is not None and positions is not None:
        q = apply_rope(q, positions, cfg.attn.rope_base)
        k = apply_rope(k, positions, cfg.attn.rope_base)
    from repro.parallel.sharding import current_rules
    rules = current_rules()
    q_heads_shardable = True
    kv_on_heads = True
    if rules is not None:
        msize = rules.mesh.shape[rules.model_axis]
        q_heads_shardable = cfg.n_heads % msize == 0
        kv_on_heads = cfg.n_kv_heads % msize == 0
    if q_heads_shardable or q.shape[1] == 1:
        q = shard_activation(q, "batch", None, "model", None)
    else:
        # heads can't shard (e.g. qwen2-0.5b's 14, whisper's 20) — shard the
        # query *sequence* over the otherwise-idle model axis so prefill
        # attention compute/memory split 16-ways (§Perf iteration D)
        q = shard_activation(q, "batch", "model", None, None)
    # kv: shard heads when divisible, else fall back to replicated — must
    # match the cache layout (launch/specs.cache_partition_specs) so decode
    # cache updates stay local (§Perf iteration A)
    if kv_on_heads:
        k = shard_activation(k, "batch", None, "model", None)
        v = shard_activation(v, "batch", None, "model", None)
    else:   # leave kv replicated on model; the cache layout (seq-sharded
        k = shard_activation(k, "batch", None, None, None)   # over model)
        v = shard_activation(v, "batch", None, None, None)   # governs
    return q, k, v


# --------------------------------------------------- chunked online softmax
def _mask(q_pos, kv_pos, *, causal, window):
    """(..., Sq, Skv) boolean validity mask from position vectors."""
    m = kv_pos[..., None, :] >= 0
    if causal:
        m &= kv_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= kv_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def _pad_to(x, axis, mult, value=0):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def chunked_attention(q, k, v, q_pos, kv_pos, *, causal=True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024):
    """Online-softmax attention in tiles.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D); q_pos: (B, Sq); kv_pos: (B, Skv)
    (negative kv positions are masked out). Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    q = _pad_to(q, 1, q_chunk)
    q_pos_p = _pad_to(q_pos, 1, q_chunk, value=-1)
    k = _pad_to(k, 1, kv_chunk)
    v = _pad_to(v, 1, kv_chunk)
    kv_pos_p = _pad_to(kv_pos, 1, kv_chunk, value=-(1 << 30))
    nq, nkv = q.shape[1] // q_chunk, k.shape[1] // kv_chunk

    # (B, nq, C, Hkv, G, D)
    qr = q.reshape(B, nq, q_chunk, Hkv, G, D)
    qpr = q_pos_p.reshape(B, nq, q_chunk)
    kr = k.reshape(B, nkv, kv_chunk, Hkv, D)
    vr = v.reshape(B, nkv, kv_chunk, Hkv, D)
    kpr = kv_pos_p.reshape(B, nkv, kv_chunk)

    def q_block(qc, qp):
        # qc: (B, C, Hkv, G, D); qp: (B, C)
        def kv_step(carry, inputs):
            m_prev, l_prev, acc = carry
            kc, vc, kp = inputs            # (B, Ck, Hkv, D), (B, Ck)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc).astype(jnp.float32)
            s *= scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            msk = _mask(qp, kp, causal=causal, window=window)  # (B, Cq, Ck)
            s = jnp.where(msk[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             kpr.transpose(1, 0, 2)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (B, C, Hkv, G, D)

    q_block = jax.checkpoint(q_block)
    out = jax.lax.map(lambda i: q_block(qr[:, i], qpr[:, i]),
                      jnp.arange(nq))                     # (nq,B,C,Hkv,G,D)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, Hq, D)
    return out[:, :Sq].astype(q.dtype)


def naive_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                    softcap=None):
    """Reference O(S^2)-memory attention (small shapes / decode / oracles)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k).astype(jnp.float32) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    msk = _mask(q_pos, kv_pos, causal=causal, window=window)
    s = jnp.where(msk[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, D)


# ----------------------------------------------------- cache quantization
def _cache_read_kv(cache, dtype):
    """Cache K/V as float ``dtype``, dequantizing int8 entries through
    their per-(position, head) scale planes. Empty slots (pos = -1) hold
    zero payload/scale and are masked by attention either way."""
    if "k_scale" in cache:
        return (dequantize_kv(cache["k"], cache["k_scale"], dtype),
                dequantize_kv(cache["v"], cache["v_scale"], dtype))
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def _kv_payload(cache, k, v):
    """Arrays to store for a K/V cache write, matching the cache layout:
    float caches get dtype-cast payloads; int8 caches get payloads
    quantized at scatter plus the scale planes for the written span."""
    if "k_scale" in cache:
        qk, sk = quantize_kv(k)
        qv, sv = quantize_kv(v)
        return {"k": qk, "k_scale": sk, "v": qv, "v_scale": sv}
    return {"k": k.astype(cache["k"].dtype),
            "v": v.astype(cache["v"].dtype)}


# ------------------------------------------------------------------- blocks
def attn_apply(cfg, p, x, positions, *, window=None, cache=None,
               use_chunked=None):
    """Self-attention over a full sequence (train/prefill).

    If ``cache`` is a dict with 'k'/'v' buffers it is *written* (prefill
    filling); returns (out, cache).
    """
    from repro.models import runtime_flags
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    if runtime_flags.COST_MODE and S > 2048:
        # single full-size chunk: loop trip counts of 1 (so cost_analysis
        # counts every op exactly once) with the same program structure and
        # sharding as the real tiled path — naive attention was tried first
        # and polluted the collective accounting with score-tensor reshards
        # that don't exist in the real program
        out = chunked_attention(q, k, v, positions, positions, causal=True,
                                window=window,
                                softcap=cfg.attn.logit_softcap,
                                q_chunk=S, kv_chunk=S)
    else:
        if use_chunked is None:
            use_chunked = S > 2048 and not runtime_flags.COST_MODE
        fn = chunked_attention if use_chunked else naive_attention
        out = fn(q, k, v, positions, positions, causal=True, window=window,
                 softcap=cfg.attn.logit_softcap)
    if cache is not None:
        L = cache["k"].shape[1]
        if S >= L:  # keep the last L positions (ring semantics)
            pay = _kv_payload(cache, k[:, S - L:], v[:, S - L:])
            cache = dict(pay, pos=positions[:, S - L:],
                         len=jnp.full((B,), S, jnp.int32))
        else:
            pay = _kv_payload(cache, k, v)
            cache = dict(
                {key: cache[key].at[:, :S].set(val)
                 for key, val in pay.items()},
                pos=cache["pos"].at[:, :S].set(positions),
                len=jnp.full((B,), S, jnp.int32))
    o = qeinsum("bshk,hkd->bsd", out, p["wo"])
    from repro.models.runtime_flags import residual_axes
    return shard_activation(o, *residual_axes()), cache


def attn_decode(cfg, p, x, positions, cache, *, window=None):
    """Single-step decode. x: (B, 1, d); cache k/v: (B, L, Hkv, D) ring
    buffer with per-row 'pos' (absolute positions, -1 = empty) and 'len'."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    L = cache["k"].shape[1]
    slot = positions[:, 0] % L                              # (B,)
    bidx = jnp.arange(x.shape[0])
    pay = _kv_payload(cache, k[:, 0], v[:, 0])
    cpos = cache["pos"].at[bidx, slot].set(positions[:, 0])
    new_cache = dict(
        {key: cache[key].at[bidx, slot].set(val)
         for key, val in pay.items()},
        pos=cpos, len=cache["len"] + 1)
    rk, rv = _cache_read_kv(new_cache, q.dtype)
    out = naive_attention(q, rk, rv, positions, cpos, causal=True,
                          window=window, softcap=cfg.attn.logit_softcap)
    o = qeinsum("bshk,hkd->bsd", out, p["wo"])
    return shard_activation(o, "batch", None, None), new_cache


def attn_prefill_chunk(cfg, p, x, positions, cache, *, window=None):
    """One chunk of an incremental prefill — the S>1 generalization of
    ``attn_decode`` that chunked prefill interleaves between decode
    segments.

    Unlike ``attn_apply`` (which assumes the cache is empty and writes the
    sequence at cache indices 0..S-1), the chunk's queries attend over the
    *cached prefix plus the chunk itself*, and the chunk's KV is then
    written at its absolute positions (ring semantics, ``pos % L``). Stale
    ring entries sharing a slot with the chunk carry positions at least a
    full window older than any query, so the window mask already excludes
    them; empty slots carry the pos = -1 sentinel and are masked the same
    way. x: (B, S, d); positions: (B, S) absolute; returns (out, cache).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    L = cache["k"].shape[1]
    ck, cv = _cache_read_kv(cache, q.dtype)
    kv_k = jnp.concatenate([ck, k], axis=1)
    kv_v = jnp.concatenate([cv, v], axis=1)
    kv_pos = jnp.concatenate([cache["pos"], positions], axis=1)
    out = naive_attention(q, kv_k, kv_v, positions, kv_pos, causal=True,
                          window=window, softcap=cfg.attn.logit_softcap)
    if S >= L:  # ring: only the chunk's last L positions survive the write
        k, v, positions = k[:, S - L:], v[:, S - L:], positions[:, S - L:]
    slots = positions % L
    bidx = jnp.arange(B)[:, None]
    pay = _kv_payload(cache, k, v)
    new_cache = dict(
        {key: cache[key].at[bidx, slots].set(val)
         for key, val in pay.items()},
        pos=cache["pos"].at[bidx, slots].set(positions),
        len=cache["len"] + S)
    o = qeinsum("bshk,hkd->bsd", out, p["wo"])
    return shard_activation(o, "batch", None, None), new_cache


def attn_verify_chunk(cfg, p, x, positions, cache, *, window=None):
    """Speculative-verify chunk: score S candidate tokens in one forward,
    bitwise-identically to running S ``attn_decode`` steps.

    ``attn_prefill_chunk`` attends the fresh chunk's K/V as raw float and
    only quantizes at the write, so under an int8 KV cache its logits
    differ (at the last ulp) from decode's — which dequantizes a token's
    own KV through its stored scale. Verify therefore mirrors decode's
    order instead: write the chunk's KV into the ring *first* (quantizing
    under int8 exactly like ``attn_decode`` does), then attend every query
    over the cache read-back. The key set each query sees matches the
    per-step decode ring — future in-chunk positions are causally masked,
    entries at or past the row's frontier hold the pos = -1 sentinel (the
    scheduler's rollback invariant), and masked entries contribute exact
    softmax zeros in the same reduction order — so greedy verify logits
    equal greedy decode logits bitwise under float *and* int8 caches.
    Requires S < L (slots are sized with spec headroom; no ring wrap).
    x: (B, S, d); positions: (B, S) absolute; returns (out, cache).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    L = cache["k"].shape[1]
    slots = positions % L
    bidx = jnp.arange(B)[:, None]
    pay = _kv_payload(cache, k, v)
    new_cache = dict(
        {key: cache[key].at[bidx, slots].set(val)
         for key, val in pay.items()},
        pos=cache["pos"].at[bidx, slots].set(positions),
        len=cache["len"] + S)
    rk, rv = _cache_read_kv(new_cache, q.dtype)
    out = naive_attention(q, rk, rv, positions, new_cache["pos"],
                          causal=True, window=window,
                          softcap=cfg.attn.logit_softcap)
    o = qeinsum("bshk,hkd->bsd", out, p["wo"])
    return shard_activation(o, "batch", None, None), new_cache


def cross_attn_apply(cfg, p, x, enc_kv):
    """Cross-attention (whisper decoder). enc_kv = (k, v) precomputed from
    encoder output: (B, T, Hkv, D) each."""
    q = qeinsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.attn.qkv_bias:
        q = q + p["bq"]
    k, v = enc_kv
    B, T = k.shape[0], k.shape[1]
    q_pos = jnp.zeros(q.shape[:2], jnp.int32)
    kv_pos = jnp.zeros((B, T), jnp.int32)
    out = naive_attention(q, k, v, q_pos, kv_pos, causal=False)
    o = qeinsum("bshk,hkd->bsd", out, p["wo"])
    return shard_activation(o, "batch", None, None)


def cross_kv(cfg, p, enc_out):
    k = qeinsum("btd,dhk->bthk", enc_out, p["wk"])
    v = qeinsum("btd,dhk->bthk", enc_out, p["wv"])
    if cfg.attn.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def make_cache(cfg, batch, max_len, *, window=None, dtype=jnp.bfloat16,
               long_ctx=False, quantized=False):
    """Allocate a KV cache. Local layers only keep ``window`` slots; global
    layers keep max_len, optionally capped (windowed-global long-ctx
    variant). ``quantized`` stores K/V as int8 with per-(position, head)
    f32 scale planes alongside (see quant/kv.py)."""
    L = max_len
    if window is not None:
        L = min(L, window)
    elif long_ctx and cfg.attn.long_ctx_window_cap is not None:
        L = min(L, cfg.attn.long_ctx_window_cap)
    hd = cfg.head_dim_
    kv_dtype = jnp.int8 if quantized else dtype
    cache = {
        "k": jnp.zeros((batch, L, cfg.n_kv_heads, hd), kv_dtype),
        "v": jnp.zeros((batch, L, cfg.n_kv_heads, hd), kv_dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if quantized:
        cache["k_scale"] = jnp.zeros((batch, L, cfg.n_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, L, cfg.n_kv_heads), jnp.float32)
    return cache
