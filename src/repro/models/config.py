"""Model configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig`` built from
composable block descriptors. A model is a stack of *pattern periods*: e.g.
gemma2 is ``("attn_local", "attn_global")`` repeated, recurrentgemma is
``("rglru", "rglru", "attn_local")`` repeated, xlstm is ``("mlstm", "slstm")``.
Homogeneous stacks use a single-element pattern. The transformer scans over
periods so the HLO stays small regardless of depth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    # d_ff of each routed expert (shared experts use the same width unless set)
    expert_d_ff: int = 0
    shared_d_ff: int = 0
    router_aux_loss_weight: float = 0.01
    capacity_factor: float = 1.25
    # 'expert' = shard expert dim on model axis; 'tensor' = shard each expert's
    # d_ff on model axis (used when num_experts isn't divisible by the axis).
    sharding: str = "auto"


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    # sliding window size; None = full attention
    window: Optional[int] = None
    logit_softcap: Optional[float] = None
    qkv_bias: bool = False
    # rotary embedding base; None = no rotary (recurrent archs, or abs-pos
    # models — see ModelConfig.abs_pos)
    rope_base: Optional[float] = 10_000.0
    # cap applied to the *global* layers' effective window at long-context
    # decode (gemma2 windowed-global variant); None = no cap
    long_ctx_window_cap: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    # block pattern, repeated to n_layers. entries:
    #   attn | attn_local | attn_global | mlstm | slstm | rglru
    pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    attn: AttnConfig = AttnConfig()
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "silu"                   # silu | gelu
    gated_mlp: bool = True              # SwiGLU-style vs plain 2-layer MLP
    final_logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    max_seq_len: int = 524_288
    # encoder-decoder (whisper): encoder layer count; 0 = decoder-only
    enc_layers: int = 0
    enc_seq_len: int = 1500             # stub frontend sequence length
    # VLM prefix: number of stub image-patch embedding tokens prepended
    vis_tokens: int = 0
    # post-attn / post-ffn extra norms (gemma2 style)
    post_norms: bool = False
    # learned absolute position embeddings (BERT / whisper decoder)
    abs_pos: bool = False
    # sLSTM/mLSTM internals
    xlstm_proj_factor: float = 2.0
    # RG-LRU internals
    rglru_rnn_width: int = 0            # 0 -> d_model
    vocab_pad_multiple: int = 256
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def layer_pattern(self) -> Tuple[str, ...]:
        """Full per-layer block list of length n_layers."""
        p = self.pattern
        reps = (self.n_layers + len(p) - 1) // len(p)
        return (p * reps)[: self.n_layers]

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern length {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def fused_qkv(self) -> bool:
        """Grouped fused-QKV layout (d, G, q_per_g + 2, hd): one column-
        parallel projection (one backward dx all-reduce) instead of three.
        Requires kv-head groups to divide the 16-way production model axis
        so the group dim shards and the q/k/v split stays shard-local
        (§Perf iteration B2)."""
        return (self.n_kv_heads % 16 == 0
                and self.n_heads % self.n_kv_heads == 0)

    @property
    def is_subquadratic(self) -> bool:
        """True if every block is recurrent or windowed attention (possibly via
        the long-context window cap), i.e. long_500k decode is admissible."""
        for b in self.pattern:
            if b in ("mlstm", "slstm", "rglru", "attn_local"):
                continue
            if b in ("attn", "attn_global"):
                if self.attn.long_ctx_window_cap is None:
                    return False
                continue
            raise ValueError(b)
        return True

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + heads)."""
        d, hd = self.d_model, self.head_dim_
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        def attn_params():
            p = d * hd * (n_q + 2 * n_kv) + n_q * hd * d
            if self.attn.qkv_bias:
                p += hd * (n_q + 2 * n_kv)
            return p
        def mlp_params(d_ff):
            return d * d_ff * (3 if self.gated_mlp else 2)
        def ffn_params():
            if self.moe is not None:
                e_ff = self.moe.expert_d_ff or self.d_ff
                s_ff = self.moe.shared_d_ff or e_ff
                p = self.moe.num_experts * mlp_params(e_ff)
                p += self.moe.num_shared_experts * mlp_params(s_ff)
                p += d * self.moe.num_experts  # router
                return p
            return mlp_params(self.d_ff)
        for blk in self.layer_pattern:
            if blk.startswith("attn"):
                total += attn_params() + ffn_params() + 2 * d
            elif blk == "rglru":
                w = self.rglru_rnn_width or d
                # linear in/out + gates + conv-ish mixing approximation
                total += d * w * 2 + 3 * w + ffn_params() + 2 * d
            elif blk == "mlstm":
                pf = self.xlstm_proj_factor
                dp = int(d * pf)
                total += d * dp * 2 + dp * 3 * (dp // max(n_q, 1)) + dp * d + 2 * d
            elif blk == "slstm":
                total += 4 * d * d + 4 * d * d + d * int(d * 4 / 3) * 2 + 2 * d
        # encoder stack (whisper)
        for _ in range(self.enc_layers):
            total += attn_params() + mlp_params(self.d_ff) + 2 * d
            total += attn_params()  # decoder cross-attention, one per dec layer
        return int(total)

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e_ff = self.moe.expert_d_ff or self.d_ff
        per_expert = self.d_model * e_ff * (3 if self.gated_mlp else 2)
        inactive = (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(full - inactive * self.n_layers)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
