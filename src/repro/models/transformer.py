"""Generic stacked model: init / forward / decode over composable blocks.

The layer stack is organized as ``n_periods`` repetitions of the config's
block pattern; parameters (and caches/states) are stacked over a leading
period axis and the stack is executed with ``jax.lax.scan`` so the lowered
HLO contains each distinct block body exactly once — this is what keeps the
40-pair × 512-device dry-run compilable.

Supports decoder-only (causal), bidirectional encoders (causal=False — used
by GECToR/BERT), encoder-decoder (whisper: ``enc_layers > 0``), VLM prefix
embeddings (``prefix_embeds``), MoE, and recurrent (xLSTM / RG-LRU) blocks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (apply_norm, embed_apply, embed_init,
                                 lm_head_apply, lm_head_init, mlp_apply,
                                 mlp_init, norm_init, pos_embed_init,
                                 split_keys)
from repro.parallel.sharding import shard_activation

ATTN_KINDS = ("attn", "attn_local", "attn_global")


# ------------------------------------------------------------------- init
def _block_init(cfg: ModelConfig, kind: str, rng, *, with_cross=False):
    ks = split_keys(rng, 6)
    if kind in ATTN_KINDS:
        p = {"norm1": norm_init(cfg), "attn": attn_mod.attn_init(cfg, ks[0]),
             "norm2": norm_init(cfg)}
        if cfg.moe is not None:
            p["moe"] = moe_mod.moe_init(cfg, ks[1])
        else:
            p["mlp"] = mlp_init(cfg, ks[1])
        if cfg.post_norms:
            p["post_norm1"] = norm_init(cfg)
            p["post_norm2"] = norm_init(cfg)
        if with_cross:
            p["norm_cross"] = norm_init(cfg)
            p["cross_attn"] = attn_mod.attn_init(cfg, ks[2])
        return p
    if kind == "mlstm":
        return {"mlstm": xlstm_mod.mlstm_init(cfg, ks[0])}
    if kind == "slstm":
        return {"slstm": xlstm_mod.slstm_init(cfg, ks[0])}
    if kind == "rglru":
        return {"rglru": rglru_mod.rglru_init(cfg, ks[0]),
                "norm2": norm_init(cfg), "mlp": mlp_init(cfg, ks[1])}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, rng):
    ks = split_keys(rng, 8 + len(cfg.pattern))
    params = {"embed": embed_init(cfg, ks[0]),
              "final_norm": norm_init(cfg)}
    if cfg.abs_pos:
        params["pos_embed"] = pos_embed_init(cfg, ks[1],
                                             min(cfg.max_seq_len, 8192))
    if not cfg.tie_embeddings:
        params["lm_head"] = lm_head_init(cfg, ks[2])

    with_cross = cfg.enc_layers > 0
    blocks = {}
    for j, kind in enumerate(cfg.pattern):
        per = []
        subkeys = split_keys(ks[3 + j], cfg.n_periods)
        for i in range(cfg.n_periods):
            per.append(_block_init(cfg, kind, subkeys[i],
                                   with_cross=with_cross))
        blocks[f"blk{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    params["blocks"] = blocks

    if cfg.enc_layers > 0:
        enc = []
        subkeys = split_keys(ks[7], cfg.enc_layers)
        for i in range(cfg.enc_layers):
            enc.append(_block_init(cfg, "attn", subkeys[i]))
        params["enc_blocks"] = {"blk0": jax.tree.map(
            lambda *xs: jnp.stack(xs), *enc)}
        params["enc_final_norm"] = norm_init(cfg)
    return params


# ------------------------------------------------------------------ caches
def make_caches(cfg: ModelConfig, batch: int, max_len: int, *,
                long_ctx: bool = False, dtype=jnp.bfloat16, kv_quant=None):
    """Stacked (over periods) decode caches/states per pattern position.

    Encoder-decoder models additionally carry a cross-attention KV cache
    ('ck'/'cv', filled once at prefill) so decode never re-runs the encoder.
    ``kv_quant="int8"`` allocates quantized self-attention caches (scale
    planes included); recurrent states and cross-attention KV stay float.
    """
    caches = {}
    for j, kind in enumerate(cfg.pattern):
        if kind in ATTN_KINDS:
            window = cfg.attn.window if kind == "attn_local" else None
            one = attn_mod.make_cache(cfg, batch, max_len, window=window,
                                      dtype=dtype, long_ctx=long_ctx,
                                      quantized=kv_quant == "int8")
            if cfg.enc_layers > 0:
                hd = cfg.head_dim_
                one["ck"] = jnp.zeros((batch, cfg.enc_seq_len,
                                       cfg.n_kv_heads, hd), dtype)
                one["cv"] = jnp.zeros((batch, cfg.enc_seq_len,
                                       cfg.n_kv_heads, hd), dtype)
        elif kind == "mlstm":
            one = xlstm_mod.mlstm_state(cfg, batch)
        elif kind == "slstm":
            one = xlstm_mod.slstm_state(cfg, batch)
        elif kind == "rglru":
            one = rglru_mod.rglru_state(cfg, batch)
        caches[f"blk{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), one)
    return caches


# ----------------------------------------------------------------- blocks
def _apply_block(cfg, kind, p, x, positions, cache, *, mode, causal,
                 long_ctx, enc_out):
    """Returns (x, new_cache, aux_losses)."""
    aux = jnp.zeros((2,), jnp.float32)  # (load_balance, router_z)
    if kind in ATTN_KINDS:
        window = cfg.attn.window if kind == "attn_local" else None
        if window is None and long_ctx and cfg.attn.long_ctx_window_cap:
            window = cfg.attn.long_ctx_window_cap
        # split the cross-attention KV cache (enc-dec) from the self cache
        cross_cache = None
        if cache is not None and "ck" in cache:
            cross_cache = (cache["ck"], cache["cv"])
            cache = {k: v for k, v in cache.items() if k not in ("ck", "cv")}
        h = apply_norm(cfg, p["norm1"], x)
        if mode == "decode":
            a, cache = attn_mod.attn_decode(cfg, p["attn"], h, positions,
                                            cache, window=window)
        elif mode == "chunk":
            a, cache = attn_mod.attn_prefill_chunk(cfg, p["attn"], h,
                                                   positions, cache,
                                                   window=window)
        elif mode == "verify":
            a, cache = attn_mod.attn_verify_chunk(cfg, p["attn"], h,
                                                  positions, cache,
                                                  window=window)
        else:
            if not causal:
                q, k, v = attn_mod._project_qkv(cfg, p["attn"], h, positions)
                a = attn_mod.naive_attention(q, k, v, positions, positions,
                                             causal=False, window=None,
                                             softcap=cfg.attn.logit_softcap)
                a = attn_mod.qeinsum("bshk,hkd->bsd", a, p["attn"]["wo"])
            else:
                a, cache = attn_mod.attn_apply(cfg, p["attn"], h, positions,
                                               window=window, cache=cache)
        if cfg.post_norms:
            a = apply_norm(cfg, p["post_norm1"], a)
        x = x + a
        if "cross_attn" in p and (enc_out is not None
                                  or cross_cache is not None):
            hc = apply_norm(cfg, p["norm_cross"], x)
            if enc_out is not None:   # prefill/train: fresh cross KV
                kv = attn_mod.cross_kv(cfg, p["cross_attn"], enc_out)
                if cross_cache is not None:   # fill the cross cache once
                    cross_cache = (kv[0].astype(cross_cache[0].dtype),
                                   kv[1].astype(cross_cache[1].dtype))
            else:                     # decode: cached cross KV, no encoder
                kv = (cross_cache[0].astype(x.dtype),
                      cross_cache[1].astype(x.dtype))
            x = x + attn_mod.cross_attn_apply(cfg, p["cross_attn"], hc, kv)
        if cache is not None and cross_cache is not None:
            cache = dict(cache, ck=cross_cache[0], cv=cross_cache[1])
        h = apply_norm(cfg, p["norm2"], x)
        if cfg.moe is not None:
            f, moe_aux = moe_mod.moe_apply(cfg, p["moe"], h)
            aux = aux + jnp.stack([moe_aux["load_balance_loss"],
                                   moe_aux["router_z"]])
        else:
            f = mlp_apply(cfg, p["mlp"], h)
        if cfg.post_norms:
            f = apply_norm(cfg, p["post_norm2"], f)
        x = x + f
    elif kind == "mlstm":
        delta, cache = xlstm_mod.mlstm_apply(cfg, p["mlstm"], x, state=cache)
        x = x + delta
    elif kind == "slstm":
        delta, cache = xlstm_mod.slstm_apply(cfg, p["slstm"], x, state=cache)
        x = x + delta
    elif kind == "rglru":
        if mode == "decode":
            delta, cache = rglru_mod.rglru_step(cfg, p["rglru"], x, cache)
        else:
            delta, cache = rglru_mod.rglru_apply(cfg, p["rglru"], x,
                                                 state=cache)
        x = x + delta
        h = apply_norm(cfg, p["norm2"], x)
        x = x + mlp_apply(cfg, p["mlp"], h)
    else:
        raise ValueError(kind)
    return x, cache, aux


# ---------------------------------------------------------------- forward
def forward(cfg: ModelConfig, params, *, tokens=None, embeds=None,
            prefix_embeds=None, positions=None, caches=None,
            mode: str = "full", causal: bool = True, long_ctx: bool = False,
            enc_tokens_embeds=None, remat: bool = False,
            return_hidden: bool = False, seq_shard: bool = False,
            unroll_periods: Optional[bool] = None):
    """Run the model.

    mode: 'full' (train/prefill from an empty cache), 'decode' (single step
    with caches), 'chunk' (incremental prefill continuation: attend over
    the cached prefix + this chunk, then extend the caches at the chunk's
    absolute ``positions`` — recurrent states simply carry across chunks),
    or 'verify' (speculative verify: like 'chunk' but write-first so the
    logits match per-step decode bitwise — see ``attn_verify_chunk``).
    unroll_periods: None = auto (unroll the period stack for single-token
    decode when ``n_periods`` is large — measured on CPU, the scan's
    per-iteration dynamic-slice of the stacked params is cheap while they
    fit in cache, but past ~16 periods that slice traffic dominates the
    S=1 step body: scan 26ms vs unrolled 15ms at 24 periods, 41ms vs 18ms
    at 32; below the crossover unrolling is 4-16% *slower* than scan).
    True/False force it.
    Returns (logits_or_hidden, new_caches, aux) where aux = (lb_loss, z_loss).
    """
    # ---- encoder (whisper) ----
    enc_out = None
    if cfg.enc_layers > 0 and enc_tokens_embeds is not None:
        eo = enc_tokens_embeds.astype(cfg.jdtype)
        eo = shard_activation(eo, "batch", None, None)
        epos = jnp.broadcast_to(jnp.arange(eo.shape[1], dtype=jnp.int32),
                                eo.shape[:2])

        def enc_body(x, p):
            x, _, _ = _apply_block(cfg, "attn", p, x, epos, None,
                                   mode="full", causal=False, long_ctx=False,
                                   enc_out=None)
            return x, None
        from repro.models import runtime_flags
        if runtime_flags.COST_MODE:       # unrolled so cost_analysis counts
            for i in range(cfg.enc_layers):
                eo, _ = enc_body(eo, jax.tree.map(
                    lambda x: x[i], params["enc_blocks"]["blk0"]))
        else:
            eo, _ = jax.lax.scan(enc_body, eo, params["enc_blocks"]["blk0"])
        enc_out = apply_norm(cfg, params["enc_final_norm"], eo)

    # ---- input embedding ----
    if embeds is not None:
        x = embeds.astype(cfg.jdtype)
    else:
        x = embed_apply(cfg, params["embed"], tokens)
        if cfg.name.startswith("gemma") or cfg.name.startswith("recurrent"):
            x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.jdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.jdtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.abs_pos and "pos_embed" in params:
        tbl = params["pos_embed"]["table"].astype(cfg.jdtype)
        x = x + tbl[positions % tbl.shape[0]]
    x = shard_activation(x, "batch", "model" if seq_shard else None, None)

    # ---- block stack: python loop over pattern positions, scan over periods
    new_caches = {} if caches is not None else None
    aux_total = jnp.zeros((2,), jnp.float32)

    def run_stack(x):
        nonlocal new_caches, aux_total
        for j, kind in enumerate(cfg.pattern):
            bp = params["blocks"][f"blk{j}"]
            bc = caches[f"blk{j}"] if caches is not None else None

            def body(carry, xs):
                xx, aux = carry
                if bc is not None:
                    p, c = xs
                else:
                    p, c = xs, None
                xx, c_new, a = _apply_block(
                    cfg, kind, p, xx, positions, c, mode=mode, causal=causal,
                    long_ctx=long_ctx, enc_out=enc_out)
                if seq_shard:
                    xx = shard_activation(xx, "batch", "model", None)
                return (xx, aux + a), c_new

            body_fn = jax.checkpoint(body) if remat else body
            xs = (bp, bc) if bc is not None else bp
            from repro.models import runtime_flags
            # crossover measured at S=1, B=4 on CPU (min-of-5 blocks):
            # scan wins up to 16 periods (unroll 1.04-1.37x slower), then
            # the scan's per-iteration param slices stop fitting in cache
            # and unroll wins >2x (24p: 26ms->15ms; 32p: 41ms->18ms)
            unroll = (unroll_periods if unroll_periods is not None
                      else mode == "decode" and cfg.n_periods > 16)
            if runtime_flags.COST_MODE:   # unrolled so cost_analysis counts
                cs_list = []              # while-loop bodies only once
                carry = (x, aux_total)
                for i in range(cfg.n_periods):
                    xi = jax.tree.map(lambda t: t[i], xs)
                    carry, c_new = body_fn(carry, xi)
                    cs_list.append(c_new)
                (x, aux_total) = carry
                cs = (jax.tree.map(lambda *ts: jnp.stack(ts), *cs_list)
                      if cs_list and cs_list[0] is not None else None)
            else:
                # decode steps fully unroll small period stacks: the scan's
                # per-iteration dynamic-slice machinery costs more than the
                # whole S=1 body (see decode_loop)
                (x, aux_total), cs = jax.lax.scan(
                    body_fn, (x, aux_total), xs,
                    unroll=cfg.n_periods if unroll else 1)
            if new_caches is not None:
                new_caches[f"blk{j}"] = cs
        return x

    # NOTE: annotating block *outputs* seq-sharded (runtime_flags.SEQ_SHARD)
    # was tried and refuted — it added resharding instead of emitting
    # reduce-scatters (§Perf gemma2 iteration B: collective +11%). The
    # carry-level seq-shard annotation below is what holds the memory win.
    x = run_stack(x)
    x = apply_norm(cfg, params["final_norm"], x)
    if return_hidden:
        return x, new_caches, aux_total
    logits = lm_head_apply(cfg, params.get("lm_head"), x,
                           embed_params=params["embed"])
    return logits, new_caches, aux_total


# ------------------------------------------------------------ entry points
def prefill(cfg, params, tokens, caches, **kw):
    return forward(cfg, params, tokens=tokens, caches=caches, mode="full",
                   **kw)


def prefill_chunk(cfg, params, tokens, positions, caches, *, long_ctx=False):
    """One chunk of an incremental prefill (chunked prefill's model core).

    tokens: (B, C) the next C prompt tokens per row; positions: (B, C)
    their absolute positions (chunk k of a prompt covers positions
    kC..kC+C-1). The chunk attends over everything already in ``caches``
    plus itself and writes its KV at those positions, so running a prompt
    through consecutive chunks is equivalent to one whole-prompt prefill —
    but each call only stalls in-flight decode for a chunk, not the whole
    prompt. All chunks except a prompt's last must be completely filled
    with real tokens (padding mid-prompt would write garbage KV below live
    positions); the last chunk may carry a padded tail, which lands beyond
    the prompt exactly like whole-prompt prefill padding does.
    """
    return forward(cfg, params, tokens=tokens, positions=positions,
                   caches=caches, mode="chunk", long_ctx=long_ctx)


def verify_chunk(cfg, params, tokens, positions, caches, *, long_ctx=False):
    """Score S candidate tokens in one forward, bitwise-identically to S
    ``decode_step`` calls (speculative decoding's verify core). Same
    signature as ``prefill_chunk``; see ``attention.attn_verify_chunk``
    for why verify writes the chunk's KV before attending while chunked
    prefill attends first."""
    return forward(cfg, params, tokens=tokens, positions=positions,
                   caches=caches, mode="verify", long_ctx=long_ctx)


def spec_round(cfg, params, draft_cfg, draft_params, tokens, positions,
               caches, draft_caches, *, k, temperature=None, top_k=None,
               seed=None, long_ctx=False):
    """One draft-and-verify round: propose ``k`` tokens with the draft
    model, then score all of them with one target ``verify_chunk``.

    tokens (B, 1): the last committed token per row; positions (B, 1): its
    absolute position (KV not yet written — the ``decode_segment``
    convention). The draft runs k + 1 sequential decode steps — the last
    one writes d_k's KV (its sample is discarded) so after a full accept
    the draft frontier matches the target's. The verify chunk covers
    [t_0, d_1..d_k] at positions p..p+k; ``verify[:, j]`` is the token the
    *target* selects at position p+j+1 given that prefix, via the same
    counter-based ``sample_logits`` as plain decode — so the committed
    stream (host-side accept: leading agreements + one correction) is
    token-identical to non-speculative decode, greedy or sampled.

    Returns (drafts (B, k), verify (B, k+1), caches, draft_caches); both
    caches have KV written through position p+k and must be rolled back to
    each row's commit boundary (``CachePool.scatter_rollback``) before the
    next read.
    """
    B = tokens.shape[0]
    tok, pos = tokens, positions
    drafts = []
    for _ in range(k):
        logits, draft_caches, _ = forward(
            draft_cfg, draft_params, tokens=tok, positions=pos,
            caches=draft_caches, mode="decode", long_ctx=long_ctx)
        nxt = sample_logits(logits[:, -1], temperature=temperature,
                            top_k=top_k, seed=seed,
                            positions=pos[:, 0] + 1)
        drafts.append(nxt)
        tok, pos = nxt[:, None], pos + 1
    _, draft_caches, _ = forward(
        draft_cfg, draft_params, tokens=tok, positions=pos,
        caches=draft_caches, mode="decode", long_ctx=long_ctx)
    drafts = jnp.stack(drafts, axis=1)                       # (B, k)
    S = k + 1
    chunk = jnp.concatenate([tokens, drafts], axis=1)        # (B, S)
    cpos = positions + jnp.arange(S, dtype=jnp.int32)[None, :]
    logits, caches, _ = forward(cfg, params, tokens=chunk, positions=cpos,
                                caches=caches, mode="verify",
                                long_ctx=long_ctx)
    flat = logits.reshape(B * S, logits.shape[-1])
    if temperature is None:
        verify = sample_logits(flat)
    else:
        # row-major repeat keeps (seed, position) pairs identical to the
        # per-step decode path's, so sampled spec-decode commits the same
        # tokens plain sampled decode would
        verify = sample_logits(flat, temperature=jnp.repeat(temperature, S),
                               top_k=jnp.repeat(top_k, S),
                               seed=jnp.repeat(seed, S),
                               positions=(cpos + 1).reshape(-1))
    return drafts, verify.reshape(B, S), caches, draft_caches


def decode_step(cfg, params, tokens, positions, caches, *, long_ctx=False,
                enc_tokens_embeds=None, unroll_periods=None):
    """tokens: (B, 1) next-token ids; positions: (B, 1) absolute positions."""
    return forward(cfg, params, tokens=tokens, positions=positions,
                   caches=caches, mode="decode", long_ctx=long_ctx,
                   enc_tokens_embeds=enc_tokens_embeds,
                   unroll_periods=unroll_periods)


def sample_logits(logits, *, temperature=None, top_k=None, seed=None,
                  positions=None):
    """Per-row token selection from last-step logits (B, V).

    ``temperature`` (B,) float32: rows with temperature <= 0 take the greedy
    argmax; others sample from softmax(logits / temperature), optionally
    restricted to the row's ``top_k`` (B,) int32 highest logits (<= 0
    disables the filter). Sampling uses a counter-based PRNG —
    ``fold_in(fold_in(key, seed_row), position_row)`` — so the token drawn
    for a given (seed, position) is deterministic regardless of batch
    composition or segment boundaries: the continuous scheduler and the
    batch-at-a-time path produce identical samples. ``temperature=None``
    short-circuits to pure argmax (no sort / no PRNG in the graph).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if temperature is None:
        return greedy
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    if top_k is not None:
        k = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V)
        srt = jnp.sort(lg, axis=-1)                      # ascending
        thresh = jnp.take_along_axis(srt, (V - k)[:, None], axis=-1)
        lg = jnp.where(lg >= thresh, lg, -jnp.inf)
    scaled = lg / jnp.maximum(temperature, 1e-6)[:, None]
    base = jax.random.PRNGKey(0x5EED)
    keys = jax.vmap(lambda s, p: jax.random.fold_in(
        jax.random.fold_in(base, s), p))(seed, positions)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temperature > 0, sampled.astype(jnp.int32), greedy)


def decode_segment(cfg, params, tokens, positions, caches, *, n_steps: int,
                   active=None, budget=None, eos_id=None, temperature=None,
                   top_k=None, seed=None, long_ctx=False):
    """Masked, sampled multi-step decode — the continuous-batching core.

    Runs ``n_steps`` decode steps as one ``jax.lax.scan`` over a fixed-width
    batch in which rows retire *in-graph*: a row stops emitting the step
    after it produces ``eos_id`` or exhausts its per-row ``budget``, without
    any host round-trip or batch reshape. The serving engine calls this in
    short segments and, between segments, swaps finished rows for newly
    admitted ones (prefill-into-slot) — step-granularity continuous batching.

    The entry point is **width-polymorphic**: every array argument shares
    one leading batch axis B, nothing in the body depends on its value, and
    under jit each distinct B is simply one compiled specialization. Rows
    are fully independent — no cross-row reduction touches the batch axis —
    so the tokens a row produces are a function of its own (cache, state)
    only, not of B or of which rows ride along. That is the contract the
    occupancy-adaptive scheduler builds on: it compacts the live rows of a
    ``CachePool`` into the smallest width tier that fits them (see
    ``serving.scheduler.width_tiers``), runs this same function at that
    width, and scatters the results home, token-identically to the
    full-width call.

    tokens (B, 1) int32: the token each row just generated; positions
    (B, 1) int32: the absolute position that token occupies (its KV is
    written there). active (B,) bool: rows that should decode (inactive rows
    re-write their frozen (token, position) KV slot each step — idempotent,
    so finished/empty slots stay valid with no gather/scatter). budget (B,)
    int32: tokens the row may still emit (the per-row max_new_tokens
    remainder). eos_id (B,) int32: per-row stop token, -1 disables.
    temperature / top_k / seed: per-row sampling, see ``sample_logits``.

    Returns (toks (B, n_steps), emitted (B, n_steps) bool, state, caches)
    where ``toks[:, t]`` is only meaningful where ``emitted[:, t]`` and
    ``state`` carries {tok, pos, active, budget, eos_hit} for the next
    segment. A row's eos token *is* emitted before the row retires.
    """
    B = tokens.shape[0]
    if active is None:
        active = jnp.ones((B,), bool)
    if budget is None:
        budget = jnp.full((B,), n_steps + 1, jnp.int32)
    if eos_id is None:
        eos_id = jnp.full((B,), -1, jnp.int32)

    def body(carry, _):
        tok, pos, act, bud, eos_hit, c = carry
        logits, c, _ = forward(cfg, params, tokens=tok, positions=pos,
                               caches=c, mode="decode", long_ctx=long_ctx)
        nxt = sample_logits(logits[:, -1], temperature=temperature,
                            top_k=top_k, seed=seed,
                            positions=pos[:, 0] + 1)
        emit = act
        nxt = jnp.where(emit, nxt, tok[:, 0]).astype(jnp.int32)
        bud = bud - emit.astype(jnp.int32)
        hit = emit & (eos_id >= 0) & (nxt == eos_id)
        eos_hit = eos_hit | hit
        act = act & ~hit & (bud > 0)
        pos = pos + emit[:, None].astype(jnp.int32)
        return (nxt[:, None], pos, act, bud, eos_hit, c), (nxt, emit)

    carry0 = (tokens, positions, active, budget, jnp.zeros((B,), bool),
              caches)
    (tok, pos, active, budget, eos_hit, caches), (toks, emits) = \
        jax.lax.scan(body, carry0, None, length=n_steps)
    state = {"tok": tok, "pos": pos, "active": active, "budget": budget,
             "eos_hit": eos_hit}
    return (jnp.swapaxes(toks, 0, 1), jnp.swapaxes(emits, 0, 1), state,
            caches)


def decode_loop(cfg, params, tokens, positions, caches, *, n_steps: int,
                long_ctx=False):
    """Greedy multi-token decode fused into one ``jax.lax.scan``.

    The always-active, argmax-only special case of ``decode_segment`` (same
    scan body; no sampling ops in the graph). ``tokens``: (B, 1) the token
    each row just generated; ``positions``: (B, 1) the absolute position
    that token occupies (its KV is written there, matching the per-step loop
    this replaces). Returns (generated (B, n_steps) int32, final caches);
    column t is the token decoded t+1 steps after ``tokens``.
    """
    toks, _, _, caches = decode_segment(cfg, params, tokens, positions,
                                        caches, n_steps=n_steps,
                                        long_ctx=long_ctx)
    return toks, caches
