"""Pytree checkpointing via msgpack (installed in this environment).

Layout: a single ``<step>.ckpt`` file holding {flat_key: (dtype, shape,
bytes)} plus a small JSON-ish manifest. Restores onto host then device_put —
fine for the example-scale models; a real multi-pod run would swap this for
per-shard async writes behind the same save/restore interface.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save(path: str, tree: Any) -> None:
    flat = _flatten(jax.device_get(tree))
    payload = {k: {"d": str(np.asarray(v).dtype),
                   "s": list(np.asarray(v).shape),
                   "b": np.ascontiguousarray(
                       np.asarray(v).view(np.uint8)
                       if np.asarray(v).dtype == jnp.bfloat16
                       else np.asarray(v)).tobytes()}
               for k, v in flat.items()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    flat = {}
    for k, rec in payload.items():
        dt, shape = rec["d"], tuple(rec["s"])
        if dt == "bfloat16":
            arr = np.frombuffer(rec["b"], np.uint8).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(rec["b"], np.dtype(dt))
        flat[k] = jnp.asarray(arr.reshape(shape))
    return _unflatten(flat)
