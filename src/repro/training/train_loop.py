"""Training step: next-token cross-entropy (+ MoE aux losses) + AdamW."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.training.optimizer import OptConfig, adamw_update


def _ce_from_logits(logits, labels, valid):
    labels_c = jnp.where(valid, labels, 0)
    # lse-based CE: never materializes a (B,S,V) log_softmax copy — the
    # fp32 convert fuses into the reduction (matters at vocab 256k)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - lab) * valid), jnp.sum(valid)


def chunked_ce(cfg, params, hidden, labels, valid, *, seq_chunk=512):
    """Cross-entropy computed per sequence chunk under jax.checkpoint.

    The naive path keeps several fp32 (B, S, V) buffers alive at once
    (logits + softcap/mask copies + their cotangents) — measured 4.2 GiB
    *each* per device for gemma2-27b train_4k (V=256k). Chunking bounds live
    logits to (B, seq_chunk, V) and remat recomputes them in backward.
    """
    from repro.models import runtime_flags
    from repro.models.layers import lm_head_apply
    B, S, D = hidden.shape
    if runtime_flags.COST_MODE or S <= seq_chunk:
        logits = lm_head_apply(cfg, params.get("lm_head"), hidden,
                               embed_params=params["embed"])
        tot, cnt = _ce_from_logits(logits, labels, valid)
        return tot / jnp.maximum(cnt, 1)
    pad = (-S) % seq_chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // seq_chunk
    hc = hidden.reshape(B, nc, seq_chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, seq_chunk).transpose(1, 0, 2)
    vc = valid.reshape(B, nc, seq_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, l, v = xs
        logits = lm_head_apply(cfg, params.get("lm_head"), h,
                               embed_params=params["embed"])
        tot, cnt = _ce_from_logits(logits, l, v)
        return (carry[0] + tot, carry[1] + cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hc, lc, vc))
    return tot / jnp.maximum(cnt, 1)


def loss_fn(cfg, params, batch, *, remat=True, seq_shard=False):
    """batch: {'tokens': (B, S+1) int32, optional 'enc_embeds',
    'prefix_embeds'}. Labels are tokens shifted by one; -1 labels masked."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    kw = {}
    if "enc_embeds" in batch:
        kw["enc_tokens_embeds"] = batch["enc_embeds"]
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    hidden, _, aux = forward(cfg, params, tokens=inputs, remat=remat,
                             seq_shard=seq_shard, return_hidden=True, **kw)
    if "prefix_embeds" in batch:       # vision prefix produces no labels
        hidden = hidden[:, batch["prefix_embeds"].shape[1]:]
    valid = labels >= 0
    nll = chunked_ce(cfg, params, hidden, labels, valid)
    lb, rz = aux[0], aux[1]
    total = nll + 0.01 * lb + 1e-3 * rz
    return total, {"nll": nll, "load_balance": lb, "router_z": rz}


def train_step(cfg, oc: OptConfig, params, opt_state, batch, *, remat=True,
               seq_shard=False, accum_steps: int = 1):
    """One optimizer step. ``accum_steps > 1`` splits the global batch into
    microbatches scanned sequentially with gradient accumulation — the
    standard memory knob when activations of the full batch don't fit."""
    if accum_steps <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat,
                              seq_shard=seq_shard),
            has_aux=True)(params)
    else:
        micro = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)

        def body(carry, mb):
            g_acc, l_acc, m_acc = carry
            (l, m), g = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, mb, remat=remat,
                                  seq_shard=seq_shard),
                has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
            return (g_acc, l_acc + l, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"nll": 0.0, "load_balance": 0.0, "router_z": 0.0}
        (grads, loss, metrics), _ = jax.lax.scan(body, (g0, 0.0, m0), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss * inv
        metrics = jax.tree.map(lambda m: m * inv, metrics)
    params, opt_state, gn = adamw_update(oc, params, grads, opt_state)
    metrics = dict(metrics, loss=loss, grad_norm=gn)
    return params, opt_state, metrics


def make_train_step(cfg, oc: OptConfig, *, remat=True, seq_shard=False,
                    accum_steps: int = 1):
    """Returns a (params, opt_state, batch) -> (params, opt_state, metrics)
    function suitable for jax.jit(in_shardings=..., out_shardings=...)."""
    return functools.partial(train_step, cfg, oc, remat=remat,
                             seq_shard=seq_shard, accum_steps=accum_steps)
