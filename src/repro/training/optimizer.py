"""AdamW with optional ZeRO-1 moment sharding (no external deps).

Moments are fp32 regardless of param dtype. Under ZeRO-1 the moment pytree is
annotated to shard its largest replicated axis over the ``data`` mesh axis —
the optimizer math is elementwise, so GSPMD keeps the update local and only
the params see cross-axis traffic (this is the beyond-paper memory
optimization recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(oc: OptConfig, step):
    warm = jnp.minimum(step / max(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(oc: OptConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / (gn + 1e-9))
    lr = lr_schedule(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + oc.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            u = u + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, gn


def zero1_spec(spec: P, shape, rules) -> P:
    """Shard the first replicated-and-divisible axis of a moment tensor over
    the data axis (ZeRO-1)."""
    if not rules.zero1:
        return spec
    dsize = rules.axis_size("data")
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0 and dim >= dsize:
            entries[i] = "data"
            return P(*entries)
    return P(*entries)


def opt_state_specs(param_specs, params, rules):
    mom = jax.tree.map(
        lambda s, p: zero1_spec(s, p.shape, rules), param_specs, params,
        is_leaf=lambda x: isinstance(x, P))
    return {"mu": mom, "nu": mom, "step": P()}
