from repro.training.optimizer import (adamw_init, adamw_update,  # noqa: F401
                                      OptConfig)
from repro.training.train_loop import (loss_fn, make_train_step,  # noqa: F401
                                       train_step)
