"""Data pipeline: deterministic synthetic token streams with document
structure, packing, and host-side prefetch — the training-side substrate.

Real deployments drop in a tokenized corpus reader with the same interface;
the synthetic stream (a mixture of Zipfian unigrams and repeated n-gram
"phrases") gives non-trivial, learnable structure so example runs show a
falling loss without shipping licensed corpora.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    n_phrases: int = 512
    phrase_len: int = 8
    phrase_prob: float = 0.5


class SyntheticLM:
    """Zipfian unigrams mixed with a bank of recurring phrases."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.default_rng(dc.seed)
        v = dc.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.phrases = rng.integers(0, v, (dc.n_phrases, dc.phrase_len))
        self.rng = rng

    def _doc(self, length: int) -> np.ndarray:
        out = []
        while sum(map(len, out)) < length:
            if self.rng.random() < self.dc.phrase_prob:
                out.append(self.phrases[self.rng.integers(self.dc.n_phrases)])
            else:
                n = self.rng.integers(4, 16)
                out.append(self.rng.choice(self.dc.vocab_size, size=n,
                                           p=self.unigram))
        return np.concatenate(out)[:length]

    def batches(self, num: Optional[int] = None) -> Iterator[dict]:
        dc = self.dc
        i = 0
        while num is None or i < num:
            toks = np.stack([self._doc(dc.seq_len + 1)
                             for _ in range(dc.batch_size)])
            yield {"tokens": toks.astype(np.int32)}
            i += 1
