"""Dry-run spec layer: input specs for every (arch x shape), admissibility
rules, cache shapes — all shape-level (no compilation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.specs import input_specs, param_shapes
from repro.models import make_caches
from repro.models.config import SHAPES

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", ALL_SHAPES)
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    B = shape.global_batch
    assert specs["tokens"].dtype == jnp.int32
    if shape.mode == "decode":
        assert specs["tokens"].shape == (B, 1)
        assert specs["positions"].shape == (B, 1)
        # enc-dec decode takes no encoder input (cross-KV is cached)
        assert "enc_embeds" not in specs
    elif shape.mode == "train":
        s_text = shape.seq_len - cfg.vis_tokens
        assert specs["tokens"].shape == (B, s_text + 1)
    else:
        s_text = shape.seq_len - cfg.vis_tokens
        assert specs["tokens"].shape == (B, s_text)
    if cfg.vis_tokens and shape.mode != "decode":
        assert specs["prefix_embeds"].shape == (B, cfg.vis_tokens,
                                                cfg.d_model)
    if cfg.enc_layers and shape.mode != "decode":
        assert specs["enc_embeds"].shape == (B, cfg.enc_seq_len, cfg.d_model)


def test_long_500k_admissibility():
    from repro.launch.dryrun import admissible  # noqa: PLC0415
    runs = {a for a in ARCHS if get_config(a).is_subquadratic}
    assert runs == {"xlstm-125m", "recurrentgemma-9b", "gemma2-27b"}


@pytest.mark.parametrize("arch", ["gemma2-27b", "recurrentgemma-9b",
                                  "whisper-large-v3"])
def test_cache_shapes_bounded(arch):
    """Local-attention layers allocate window-sized ring buffers; global
    layers get capped at long context; enc-dec carries cross-KV."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: make_caches(cfg, 1, 524_288, long_ctx=True))
    for j, kind in enumerate(cfg.pattern):
        blk = shapes[f"blk{j}"]
        if kind == "attn_local":
            assert blk["k"].shape[2] == cfg.attn.window
        elif kind in ("attn", "attn_global") and cfg.attn.long_ctx_window_cap:
            assert blk["k"].shape[2] <= cfg.attn.long_ctx_window_cap
        if cfg.enc_layers and "ck" in blk:
            assert blk["ck"].shape[2] == cfg.enc_seq_len


def test_param_shapes_eval_only():
    """Full-size 27B param tree materializes as ShapeDtypeStructs only."""
    import math
    shapes = param_shapes(get_config("gemma2-27b"))
    total = sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
    assert total > 25e9                     # full-size, never allocated
    assert all(isinstance(x, jax.ShapeDtypeStruct)
               for x in jax.tree.leaves(shapes))
