"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The offline test environment cannot pip-install hypothesis, which made
three test modules fail at *collection*. This shim implements the tiny
subset the suite uses — ``given``/``settings`` decorators plus the
``integers``/``sampled_from``/``booleans``/``floats`` strategies — by
drawing a fixed number of examples from a seeded ``random.Random``, so
property tests still execute (reproducibly) instead of being skipped.

Installed by ``conftest.py`` into ``sys.modules`` only when the real
hypothesis is absent; with hypothesis available the shim is inert.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_EXAMPLES = 5


class _Strategy:
    """A strategy is just a sampler: rng -> value."""

    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def settings(**kw):
    """Records max_examples on the decorated test; other knobs ignored."""
    def deco(fn):
        fn._shim_max_examples = kw.get("max_examples", _DEFAULT_EXAMPLES)
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES))
            rng = random.Random(0)  # deterministic across runs
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        # hide the drawn parameters from pytest's fixture resolution (it
        # introspects the signature copied over by functools.wraps)
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        return wrapper
    return deco


def install():
    """Register shim modules as ``hypothesis`` / ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__shim__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
