"""repro-lint analyzer tests.

Three layers, per the fixture convention in docs/ANALYSIS.md:

* each pass over its known-bad fixture flags exactly the lines carrying
  ``# EXPECT: <pass>`` (no misses, no extras);
* each pass over its known-good fixture — the sanctioned repo idioms —
  stays silent;
* a meta-test mirrors ``tools/lint.py --strict`` over ``src/`` (same
  passes, same baseline, same hygiene rules), so tier-1 itself fails on
  a new real finding, a stale suppression, or an unjustified one.

Fixtures are parsed, never imported — the analyzer itself imports no
jax, so this whole file runs without an accelerator stack.
"""
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (Baseline, PASSES, load_modules, run_passes)

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
BASELINE = REPO / "tools" / "lint_baseline.txt"

_EXPECT = re.compile(r"#\s*EXPECT:\s*([A-Za-z_][\w]*)")


def expected_lines(path: Path):
    """pass_id -> set of 1-based line numbers carrying its EXPECT tag."""
    out = {}
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = _EXPECT.search(line)
        if m:
            out.setdefault(m.group(1), set()).add(i)
    return out


def run_one(pass_id: str, fixture: Path):
    mods = load_modules(REPO, [fixture])
    return run_passes(mods, select=[pass_id])


@pytest.mark.parametrize("pass_id", sorted(PASSES))
def test_fixtures_exist(pass_id):
    assert (FIXTURES / f"{pass_id}_bad.py").exists()
    assert (FIXTURES / f"{pass_id}_good.py").exists()


@pytest.mark.parametrize("pass_id", sorted(PASSES))
def test_known_bad_flags_expected_lines(pass_id):
    fixture = FIXTURES / f"{pass_id}_bad.py"
    want = expected_lines(fixture).get(pass_id, set())
    assert want, f"{fixture.name} carries no EXPECT: {pass_id} markers"
    findings = run_one(pass_id, fixture)
    got = {f.line for f in findings}
    assert got == want, (
        f"{pass_id} over {fixture.name}: flagged {sorted(got)}, "
        f"expected {sorted(want)}\n"
        + "\n".join(f.render() for f in findings))


@pytest.mark.parametrize("pass_id", sorted(PASSES))
def test_known_good_is_clean(pass_id):
    fixture = FIXTURES / f"{pass_id}_good.py"
    findings = run_one(pass_id, fixture)
    assert not findings, "\n".join(f.render() for f in findings)


def test_findings_carry_location_and_hint():
    findings = run_one("recompile", FIXTURES / "recompile_bad.py")
    assert findings
    for f in findings:
        assert f.path.endswith("recompile_bad.py")
        assert f.line > 0 and f.qualname and f.message
        assert f.hint, "every finding ships a fix-hint"
        rendered = f.render()
        assert f"{f.path}:{f.line}:" in rendered and "[recompile]" in rendered


# ------------------------------------------------------------- baseline
def test_baseline_suppresses_and_tracks_stale(tmp_path):
    bl_file = tmp_path / "baseline.txt"
    bl_file.write_text(
        "recompile | */recompile_bad.py | predict | * | fixture demo\n"
        "recompile | */recompile_bad.py | no_such_scope | * | stale entry\n")
    bl = Baseline.load(bl_file)
    assert not bl.errors
    findings = run_one("recompile", FIXTURES / "recompile_bad.py")
    kept = bl.filter(findings)
    assert len(kept) == len(findings) - 1      # exactly predict suppressed
    assert all(f.qualname != "predict" for f in kept)
    stale = bl.unused()
    assert len(stale) == 1 and stale[0].scope == "no_such_scope"


def test_baseline_rejects_missing_justification(tmp_path):
    bl_file = tmp_path / "baseline.txt"
    bl_file.write_text("locks | src/x.py | * | * |\n"
                       "locks | too | few | fields\n")
    bl = Baseline.load(bl_file)
    assert len(bl.errors) == 2


# ---------------------------------------------------- src/ stays clean
def test_src_is_finding_free_under_strict():
    """The exact --strict contract, in-process: no unsuppressed findings
    on src/, no baseline format errors, no stale entries."""
    findings = run_passes(load_modules(REPO))
    baseline = Baseline.load(BASELINE)
    assert not baseline.errors, "\n".join(baseline.errors)
    kept = baseline.filter(findings)
    assert not kept, "\n".join(f.render() for f in kept)
    stale = baseline.unused()
    assert not stale, f"stale baseline entries: {stale}"


def test_lint_cli_strict_exits_zero():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint.py"), "--strict"],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


def test_gector_inline_jit_stays_fixed():
    """The PR-8 motivating finding: core/gector.py must keep its jit at
    module level (the recompile pass would flag an inline regression)."""
    gector = REPO / "src" / "repro" / "core" / "gector.py"
    findings = run_one("recompile", gector)
    assert not findings, "\n".join(f.render() for f in findings)
    assert "_jit_gector_forward = jax.jit(gector_forward" \
        in gector.read_text()
