"""The docs drift guard as a tier-1 test: intra-repo links in README /
ROADMAP / docs resolve, and docs/TUNING.md documents every EngineConfig
field. CI also runs the same checker standalone (`docs` job, no jax);
keeping it in the suite means a PR cannot go green with rotten docs."""
import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", ROOT / "tools" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_intra_repo_links_resolve():
    assert check_docs.check_links(ROOT) == []


def test_tuning_documents_every_engine_config_field():
    fields = check_docs.engine_config_fields(ROOT)
    assert "segment_width" in fields          # ast parse sanity
    assert check_docs.check_tuning_covers_config(ROOT) == []


def test_expected_docs_exist():
    for name in ("ARCHITECTURE.md", "DEPLOY_LAB.md", "TUNING.md"):
        assert (ROOT / "docs" / name).exists(), name
