"""The paper's study layer: environments data integrity, perf-model fit,
cost analysis, findings validation, corpus statistics, edit-tag algebra."""
import numpy as np
import pytest

from repro.core import analysis, costmodel, perfsim
from repro.core.corpus import CorpusConfig, GECCorpus
from repro.core.environments import (INSTANCES, MACHINES, MEASURED,
                                     NS_LADDER, PROVIDERS, instance)
from repro.core.tags import KEEP, TagVocab, apply_edits, edit_f_beta


# ------------------------------------------------------------ environments
def test_experiment_matrix_is_complete():
    # 21 paper scenarios + 1 beyond-paper TPU row
    assert len(INSTANCES) == 22
    for prov in PROVIDERS:
        for m in MACHINES:
            inst = instance(prov, m)
            assert inst.vcpus in (4, 8)
            cells = MEASURED[prov][m]
            assert tuple(sorted(cells)) == tuple(sorted(NS_LADDER))
            for ns in NS_LADDER:
                lat, cpu, ram = cells[ns]
                assert 0 < lat < 100 and 0 <= cpu <= 100 and 0 < ram <= 100


def test_gpu_machines_have_gpu_and_cost_more():
    for prov in PROVIDERS:
        cpu_costs = [instance(prov, m).monthly_cost_usd for m in "ABCDE"]
        for m in "FG":
            inst = instance(prov, m)
            assert inst.gpu == "NVIDIA T4"
            assert inst.monthly_cost_usd > max(cpu_costs)


# ---------------------------------------------------------------- perfsim
def test_perfsim_fit_quality():
    summary = perfsim.validation_summary()
    assert summary["mean_mape"] < 0.40          # calibrated model tracks
    # GPU machines must fit extremely well (smooth curves)
    models = perfsim.fit_all()
    for prov in PROVIDERS:
        assert models[prov]["G"].mape < 0.5


def test_perfsim_monotone_in_load():
    m = perfsim.fit_machine("AWS", "C")
    lat = m.predict_latency(np.array(NS_LADDER))
    assert np.all(np.diff(lat) >= 0)


def test_throughput_ordering_gpu_vs_cpu():
    models = perfsim.fit_all()
    for prov in PROVIDERS:
        gpu_rate = min(models[prov][m].rate for m in "FG")
        cpu_rate = max(models[prov][m].rate for m in "ABCDE")
        assert gpu_rate > cpu_rate


# --------------------------------------------------------------- costmodel
def test_gpu_cost_premium_matches_table5():
    prem = costmodel.gpu_cost_premium()
    assert 2.0 < prem["overall"] < 3.0           # ~2.54x from Table 5
    gf = costmodel.machine_g_vs_f_premium()
    assert abs(gf["AWS"] - 0.43) < 0.02          # paper: 43%
    assert abs(gf["GCP"] - 0.35) < 0.02          # paper: 35%
    assert abs(gf["Azure"] - 0.43) < 0.02        # paper: 43%


def test_c_vs_e_saving_aws():
    saving = costmodel.machine_c_vs_e_saving()
    assert abs(saving["AWS"] - 0.487) < 0.02     # paper: ~50% on AWS


def test_slo_capacity_paper_cells():
    # "machine C processing up to 32 sentences concurrently in under 2 s"
    assert costmodel.max_ns_within_slo("AWS", "C") == 32
    assert costmodel.max_ns_within_slo("AWS", "A") == 4


# ---------------------------------------------------------------- findings
def test_all_findings_hold():
    f = analysis.all_findings()
    for key in ("gpu_latency_dominance", "gpu_cost_premium",
                "cache_dominance", "ram_non_interference",
                "low_power_cpu_threshold"):
        assert f[key]["holds"], (key, f[key])


def test_cache_regression_dwarfs_clock():
    reg = perfsim.cpu_only_feature_regression()
    assert reg["coef"]["cache_gb"] > 3 * abs(reg["coef"]["clock_ghz"])


# ------------------------------------------------------------------ corpus
def test_corpus_reproduces_nucle_statistics():
    stats = GECCorpus(CorpusConfig(seed=1)).stats(400)
    assert abs(stats["tokens_per_sentence"] - 23) < 3
    assert 0.02 < stats["error_rate"] < 0.15     # "low error frequency"


def test_corruption_tags_invert_to_clean():
    """Applying the GOLD tags to the corrupted source must reconstruct the
    clean sentence — the generator's core invariant."""
    corpus = GECCorpus(CorpusConfig(seed=3, error_rate=0.3))
    checked = 0
    for src, tags, clean in corpus.generate(50):
        fixed = apply_edits(corpus.vocab, src, tags)
        assert list(fixed) == list(clean), (src, tags, clean)
        checked += 1
    assert checked == 50


# -------------------------------------------------------------------- tags
def test_tag_vocab_roundtrip():
    v = TagVocab(100)
    for w in (0, 5, 99):
        assert v.word_of(v.append(w)) == w and v.is_append(v.append(w))
        assert v.word_of(v.replace(w)) == w and v.is_replace(v.replace(w))
    assert v.n_tags == 202


def test_edit_fbeta_perfect_and_empty():
    g = np.array([[KEEP, 3, KEEP, 5]])
    mask = np.ones_like(g, bool)
    perfect = edit_f_beta(g, g, mask)
    assert perfect["f0.5"] == pytest.approx(1.0)
    none = edit_f_beta(np.zeros_like(g), g, mask)
    assert none["f0.5"] == 0.0
