"""Distribution layer: partition-spec derivation, divisibility guards,
mesh construction, rule policies — and a tiny-mesh end-to-end jit."""
import numpy as np

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.specs import cache_partition_specs
from repro.models import init_params, make_caches
from repro.parallel.sharding import (MeshRules, param_partition_specs,
                                     rules_for, use_rules)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _fake_rules(mesh=None, **kw):
    return MeshRules(mesh=mesh or _mesh11(), **kw)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_cover_and_divide(arch):
    """Every param leaf gets a spec whose sharded dims divide a 16-way
    model axis / 16-way data axis (checked against full-size configs via
    eval_shape, no allocation)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))

    class R:
        class mesh:
            shape = {"data": 16, "model": 16}
        batch_axes = ("data",)
        model_axis = "model"
        shard_attn_heads = cfg.n_heads % 16 == 0
        shard_kv_heads = cfg.n_kv_heads % 16 == 0
        expert_mode = ("tensor" if cfg.moe and cfg.moe.num_experts % 16
                       else "expert")
        zero1 = True

    specs = param_partition_specs(shapes, R())
    leaves_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    leaves_p = jax.tree.leaves(shapes)
    assert len(leaves_s) == len(leaves_p)
    for sds, spec in zip(leaves_p, leaves_s):
        assert len(spec) <= len(sds.shape)
        for dim, ax in zip(sds.shape, tuple(spec)):
            if ax is not None:
                size = np.prod([R.mesh.shape[a] for a in
                                (ax if isinstance(ax, tuple) else (ax,))])
                assert dim % size == 0, (arch, sds.shape, spec)


def test_rules_for_policies():
    mesh = _mesh11()

    class M:  # 16-way model axis stand-in
        shape = {"data": 16, "model": 16}
        size = 256
    # qwen2-0.5b: 14 heads -> attention replicated
    r = rules_for(get_config("qwen2-0.5b"), M())
    assert not r.shard_attn_heads
    # qwen2-moe: 60 experts -> tensor-parallel experts
    r = rules_for(get_config("qwen2-moe-a2.7b"), M())
    assert r.expert_mode == "tensor"
    # moonshot: 64 experts -> expert-parallel
    r = rules_for(get_config("moonshot-v1-16b-a3b"), M())
    assert r.expert_mode == "expert"
    # gemma2: fully shardable
    r = rules_for(get_config("gemma2-27b"), M())
    assert r.shard_attn_heads and r.shard_kv_heads


def test_cache_specs_shard_seq_when_batch_is_one():
    cfg = get_config("gemma2-27b")

    class M:
        shape = {"data": 16, "model": 16}
    rules = MeshRules(mesh=M(), batch_axes=("data",))
    shapes = jax.eval_shape(
        lambda: make_caches(cfg, 1, 524_288, long_ctx=True))
    specs = cache_partition_specs(cfg, shapes, rules, batch=1)
    k_spec = specs["blk0"]["k"]
    assert tuple(k_spec)[1] is None          # batch unsharded
    assert "data" in str(k_spec)             # sequence sharded instead


def test_kv_cache_seq_fallback():
    """kv heads that don't divide the model axis -> cache shards its
    sequence dim over 'model' instead (§Perf iteration A: head_dim sharding
    was refuted — GSPMD all-gathered the fp32 cache for the QK dot)."""
    cfg = get_config("stablelm-12b")          # kv=8 < 16

    class M:
        shape = {"data": 16, "model": 16}
    rules = MeshRules(mesh=M(), batch_axes=("data",))
    shapes = jax.eval_shape(lambda: make_caches(cfg, 128, 32_768))
    specs = cache_partition_specs(cfg, shapes, rules, batch=128)
    k_spec = tuple(specs["blk0"]["k"])
    assert k_spec[2] == "model" and k_spec[3] is None and k_spec[4] is None


def test_shard_activation_noop_without_rules():
    from repro.parallel.sharding import shard_activation
    x = jnp.ones((4, 4))
    assert shard_activation(x, "batch", None) is x


def test_end_to_end_tiny_mesh_jit():
    """Full pipeline under a real (1x1) mesh with rules active."""
    cfg = get_config("gemma2-27b", smoke=True)
    mesh = _mesh11()
    rules = MeshRules(mesh=mesh)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    with use_rules(rules), mesh:
        from repro.models import forward
        logits, _, _ = jax.jit(
            lambda p, t: forward(cfg, p, tokens=t))(params, toks)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()


def test_production_mesh_shapes():
    # requires the 512-host-device trick -> only verify the builder logic
    from repro.launch.mesh import make_production_mesh
    if jax.device_count() >= 512:
        m = make_production_mesh(multi_pod=True)
        assert m.shape == {"pod": 2, "data": 16, "model": 16}
    else:
        with pytest.raises(Exception):
            make_production_mesh(multi_pod=True)
