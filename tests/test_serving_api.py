"""Serving API v2: typed request lifecycle (GenerationRequest ->
RequestHandle -> GenerationResult), streaming, per-row eos/budget stops,
seeded sampling, step-level continuous batching (mid-decode joins,
batch-at-a-time equivalence), priority scheduling, and the admission-control
edge cases (parked-cancel slot safety, close() failing parked + queued,
RequestTooLong through the handle)."""
import threading
import time
from concurrent.futures import CancelledError

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import (EngineConfig, GenerationRequest, GenerationResult,
                           RequestTooLong, SamplingParams, ServingEngine)

CFG = get_config("qwen2-0.5b", smoke=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.RandomState(11)


def _engine(**kw):
    base = dict(mode="decoder", max_batch=4, max_new_tokens=6,
                pad_buckets=(16,), decode_segment=2)
    base.update(kw)
    return ServingEngine(CFG, PARAMS, EngineConfig(**base))


def _prompt(n=None):
    return RNG.randint(0, CFG.vocab_size, (n or RNG.randint(3, 12),))


# --------------------------------------------------------- request lifecycle
def test_generate_returns_typed_result_with_timing():
    eng = _engine()
    try:
        h = eng.generate(GenerationRequest(tokens=_prompt(),
                                           request_id="req-1"))
        res = h.result(timeout=300)
        assert isinstance(res, GenerationResult)
        assert res.request_id == "req-1"
        assert res.finish_reason == "length"
        assert res.tokens.shape == (6,) and res.tokens.dtype == np.int32
        t = res.timing
        assert t.queue_s >= 0 and t.prefill_s >= 0 and t.decode_s >= 0
        assert t.total_s == pytest.approx(t.queue_s + t.prefill_s
                                          + t.decode_s)
    finally:
        eng.close()


def test_per_request_max_new_tokens_budget():
    eng = _engine()
    try:
        h2 = eng.generate(_prompt(), SamplingParams(max_new_tokens=2))
        h5 = eng.generate(_prompt(), SamplingParams(max_new_tokens=5))
        r2, r5 = h2.result(timeout=300), h5.result(timeout=300)
        assert len(r2.tokens) == 2 and r2.finish_reason == "length"
        assert len(r5.tokens) == 5 and r5.finish_reason == "length"
    finally:
        eng.close()


def test_eos_stops_row_early_with_reason_eos():
    eng = _engine()
    try:
        p = _prompt()
        greedy = eng.generate(p).result(timeout=300).tokens
        eos = int(greedy[0])   # first emitted token => stops after 1
        res = eng.generate(p, SamplingParams(eos_id=eos)).result(timeout=300)
        assert res.finish_reason == "eos"
        assert res.tokens.tolist() == [eos]     # eos token is included
        # an eos somewhere mid-stream trims there (first occurrence)
        later = next((i for i, t in enumerate(greedy[1:], 1)
                      if t != greedy[0]), None)
        if later is not None:
            res2 = eng.generate(p, SamplingParams(
                eos_id=int(greedy[later]))).result(timeout=300)
            assert res2.finish_reason == "eos"
            assert res2.tokens.tolist() == greedy[:later + 1].tolist()
    finally:
        eng.close()


def test_streaming_iterator_yields_all_tokens():
    eng = _engine()
    try:
        h = eng.generate(_prompt())
        streamed = list(h)
        assert streamed == h.result(timeout=10).tokens.tolist()
        assert list(h) == []      # re-iteration terminates, never blocks
    finally:
        eng.close()


def test_sampling_params_validated_through_handle():
    eng = _engine()
    try:
        with pytest.raises(ValueError):
            eng.generate(_prompt(),
                         SamplingParams(max_new_tokens=99)).result(10)
        with pytest.raises(ValueError):
            eng.generate(_prompt(),
                         SamplingParams(temperature=-1.0)).result(10)
        assert eng.generate(_prompt()).result(timeout=300) is not None
    finally:
        eng.close()


def test_seeded_sampling_deterministic_and_topk1_is_greedy():
    eng = _engine()
    try:
        p = _prompt()
        a = eng.generate(p, SamplingParams(temperature=0.7, top_k=8,
                                           seed=5)).result(300).tokens
        b = eng.generate(p, SamplingParams(temperature=0.7, top_k=8,
                                           seed=5)).result(300).tokens
        assert (a == b).all()                   # same seed -> same tokens
        g = eng.generate(p).result(300).tokens
        k1 = eng.generate(p, SamplingParams(temperature=2.0,
                                            top_k=1)).result(300).tokens
        assert (g == k1).all()                  # top_k=1 collapses to greedy
    finally:
        eng.close()


def test_encoder_mode_rejects_generate():
    cfg = get_config("gector-base", smoke=True)
    eng = ServingEngine(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                        EngineConfig(mode="encoder", max_batch=2))
    try:
        with pytest.raises(ValueError):
            eng.generate(np.zeros(4, np.int32))
    finally:
        eng.close()


# ------------------------------------------------------ continuous batching
def test_mid_decode_join_observable_in_metrics():
    """A request submitted while another decodes must join the in-flight
    batch (continuous batching), not wait behind it."""
    eng = _engine(max_new_tokens=24, decode_segment=2)
    try:
        eng.generate(_prompt()).result(timeout=300)   # warm the compiles
        h1 = eng.generate(_prompt())
        it = iter(h1)
        next(it)                     # first segment done => decode underway
        h2 = eng.generate(_prompt())
        r1, r2 = h1.result(timeout=300), h2.result(timeout=300)
        assert len(r1.tokens) == 24 and len(r2.tokens) == 24
        m = eng.metrics()
        assert m["joins_mid_flight"] >= 1
        assert m["decode_segments"] > 0
        assert m["batch_occupancy_mean"] > 0
    finally:
        eng.close()


def test_continuous_matches_batch_at_a_time_greedy():
    """Acceptance: the scan-segment continuous path is token-identical to
    the legacy batch-at-a-time path under greedy sampling."""
    prompts = [_prompt() for _ in range(3)]
    outs = {}
    for cont in (False, True):
        eng = _engine(continuous=cont)
        try:
            hs = [eng.generate(p) for p in prompts]
            outs[cont] = [h.result(timeout=300).tokens for h in hs]
        finally:
            eng.close()
    for a, b in zip(outs[False], outs[True]):
        assert (a == b).all()


def test_batch_at_a_time_still_serves_v2_requests():
    eng = _engine(continuous=False)
    try:
        res = eng.generate(_prompt(),
                           SamplingParams(max_new_tokens=3)).result(300)
        assert len(res.tokens) == 3 and res.finish_reason == "length"
        assert res.timing.queue_s >= 0
    finally:
        eng.close()


def test_batch_at_a_time_honors_mid_serve_cancel_flag():
    """The batch worker's whole serve is one segment: a cancel landing
    mid-serve must still surface as finish_reason='cancelled'."""
    eng = _engine(continuous=False)
    try:
        h = eng.generate(_prompt())
        h._cancel.set()        # deterministically: flag set, future races on
        res = h.result(timeout=300)
        assert res.finish_reason == "cancelled"
        assert h.cancelled()
    finally:
        eng.close()


def test_priority_orders_pending_requests():
    """With one slot, the high-priority request submitted last must be
    served before the earlier low-priority one."""
    eng = _engine(max_batch=1, max_new_tokens=8)
    try:
        eng.generate(_prompt()).result(timeout=300)   # warm compiles
        order = []
        blocker = eng.generate(_prompt())             # occupies the slot
        lo = eng.generate(_prompt(), priority=0)
        hi = eng.generate(_prompt(), priority=5)
        lo.add_done_callback(lambda _f: order.append("lo"))
        hi.add_done_callback(lambda _f: order.append("hi"))
        for h in (blocker, lo, hi):
            h.result(timeout=300)
        assert order.index("hi") < order.index("lo")
    finally:
        eng.close()


def test_cancel_mid_decode_finishes_cancelled():
    eng = _engine(max_new_tokens=24, decode_segment=2)
    try:
        eng.generate(_prompt()).result(timeout=300)   # warm compiles
        h = eng.generate(_prompt())
        it = iter(h)
        next(it)                                      # decode underway
        assert h.cancel()
        res = h.result(timeout=300)
        assert res.finish_reason == "cancelled"
        assert 0 < len(res.tokens) < 24               # partial output kept
        assert h.cancelled()
    finally:
        eng.close()


# ------------------------------------------------- admission-control edges
def test_parked_cancel_does_not_leak_admission_slot():
    eng = _engine(max_inflight=1, max_new_tokens=4)
    try:
        eng.generate(_prompt()).result(timeout=300)   # warm compiles
        a = eng.generate(_prompt())                   # holds the one slot
        b = eng.generate(_prompt())                   # parked
        c = eng.generate(_prompt())                   # parked behind b
        assert b.cancel()
        with pytest.raises(CancelledError):
            b.result(timeout=10)
        # a's slot must hand over past the cancelled b straight to c
        assert a.result(timeout=300).finish_reason == "length"
        assert c.result(timeout=300).finish_reason == "length"
    finally:
        eng.close()


def test_close_fails_parked_and_queued_requests():
    eng = _engine(max_inflight=1, max_new_tokens=16)
    hs = [eng.generate(_prompt()) for _ in range(4)]
    eng.close()
    failures = 0
    for h in hs:
        try:
            h.result(timeout=30)
        except RuntimeError:
            failures += 1
            with pytest.raises(RuntimeError):
                list(h)    # stream must terminate (re-raising), not hang
    assert failures >= 2   # at least the parked ones fail fast


def test_request_too_long_surfaces_through_handle():
    eng = _engine()
    try:
        h = eng.generate(np.zeros(64, np.int32))      # > 16 bucket
        with pytest.raises(RequestTooLong):
            h.result(timeout=10)
        with pytest.raises(RequestTooLong):
            list(h)                                   # stream re-raises
        assert h.done()
    finally:
        eng.close()


def test_prefill_failure_fails_request_without_leaking_slots():
    """A transient error during prefill-into-slot must surface to the
    affected request's future (not strand it RUNNING forever), release its
    pool slot, and leave the engine serving."""
    eng = _engine()
    try:
        eng.generate(_prompt()).result(timeout=300)   # warm compiles
        real = eng._prefill_fn()
        calls = {"n": 0}

        def flaky(*a, **kw):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("injected prefill failure")
            return real(*a, **kw)

        eng._compiled["cont_prefill"] = flaky
        h = eng.generate(_prompt())
        with pytest.raises(RuntimeError, match="injected"):
            h.result(timeout=60)
        pool = eng._get_pool(16)
        assert pool.free_slots == eng.ec.max_batch    # slot released
        ok = eng.generate(_prompt()).result(timeout=300)
        assert ok.finish_reason == "length"           # engine still serves
    finally:
        eng.close()


def test_metrics_empty_engine_reports_zero_requests():
    eng = _engine()
    try:
        m = eng.metrics()
        assert m["requests"] == 0
        assert m["latency_mean_s"] is None
        assert m["latency_p50_s"] is None and m["latency_p95_s"] is None
    finally:
        eng.close()
