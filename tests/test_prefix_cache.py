"""Prefix cache: shared-prompt KV reuse with copy-on-reference slots.

Trie properties (hypothesis): the byte budget is never exceeded, a
referenced entry is never evicted, lookup returns the deepest stored
prefix strictly shorter than the prompt (partial matches fall back to the
shallower entry). Engine acceptance: warm hits produce token-identical
output to a cold engine under greedy AND sampled decode, the measured
window stays compile-clean with the cache on, hit/miss/insert/evict
counters surface per lane, a tiny byte budget forces evictions without
breaking correctness, cancel mid-suffix-prefill leaks no slot or
reference, and unsupported configs are rejected at engine init."""
import random
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.kvcache import PrefixTrie

CFG = get_config("qwen2-0.5b", smoke=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.RandomState(31)


def _engine(**kw):
    base = dict(mode="decoder", max_batch=4, max_new_tokens=6,
                pad_buckets=(32,), decode_segment=2, prefill_chunk=8,
                prefix_cache=True)
    base.update(kw)
    return ServingEngine(CFG, PARAMS, EngineConfig(**base))


def _prompt(n):
    return RNG.randint(0, CFG.vocab_size, (n,))


# ------------------------------------------------------------ trie properties
CHUNK = 4
ENTRY_BYTES = 100


def _toks(rng, n_chunks, fam):
    """A prompt of n_chunks full chunks drawn from family ``fam`` — prompts
    in one family share every chunk prefix, across families they share
    none (chunk 0 already differs)."""
    return [fam * 1000 + i for i in range(n_chunks * CHUNK)]


def _simulate(seed, capacity_entries):
    """Random insert/lookup/release traffic against one trie, enforcing
    the store's discipline (make_room before every attach), checking the
    invariants after every op. Returns the trie for final checks."""
    rng = random.Random(seed)
    trie = PrefixTrie(CHUNK, capacity_entries * ENTRY_BYTES)
    held = []                                   # (entry, tokens) refs we hold
    for _ in range(40):
        op = rng.random()
        fam = rng.randint(0, 2)
        n = rng.randint(1, 5)
        toks = _toks(rng, n, fam)
        if op < 0.5:                            # insert at depth n
            if not trie.has_entry(toks, n):
                victims = trie.make_room(ENTRY_BYTES)
                if victims is not None:
                    trie.attach(toks, n, ENTRY_BYTES, slot=len(trie.entries))
        elif op < 0.8:                          # lookup (acquires a ref)
            e = trie.lookup(toks + [7])         # +1 token past the chunks
            if e is not None:
                assert e.n_tokens <= len(toks)  # never the full prompt
                held.append(e)
        elif held:                              # release a held ref
            trie.release(held.pop(rng.randrange(len(held))))
        # invariants
        assert trie.bytes <= trie.capacity
        assert trie.bytes == len(trie.entries) * ENTRY_BYTES
        for e in held:
            assert e in trie.entries            # referenced -> never evicted
    return trie, held


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**9), cap=st.integers(1, 4))
def test_trie_budget_and_refs_hold_under_random_traffic(seed, cap):
    trie, held = _simulate(seed, cap)
    for e in held:                              # cleanup path stays sound
        trie.release(e)
        assert e.refs >= 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**9), deep=st.integers(2, 6))
def test_trie_lookup_returns_deepest_strictly_shorter(seed, deep):
    rng = random.Random(seed)
    trie = PrefixTrie(CHUNK, 100 * ENTRY_BYTES)
    toks = _toks(rng, deep, fam=0)
    depths = sorted(rng.sample(range(1, deep + 1), rng.randint(1, deep)))
    for d in depths:
        trie.attach(toks, d, ENTRY_BYTES, slot=d)
    # probe at every prompt length: the match is the deepest stored depth
    # whose prefix is strictly shorter than the prompt
    for probe_len in range(1, deep * CHUNK + 2):
        want = max((d for d in depths if d * CHUNK < probe_len), default=None)
        e = trie.lookup(toks[:probe_len] + ([] if probe_len <= deep * CHUNK
                                            else [9]))
        if want is None:
            assert e is None
        else:
            assert e is not None and e.n_tokens == want * CHUNK
            trie.release(e)


def test_trie_partial_prefix_falls_back_to_shallower_entry():
    trie = PrefixTrie(CHUNK, 100 * ENTRY_BYTES)
    toks = _toks(random.Random(0), 3, fam=0)
    trie.attach(toks, 1, ENTRY_BYTES, slot=0)
    trie.attach(toks, 3, ENTRY_BYTES, slot=1)
    # diverges inside chunk 1: only the depth-1 entry matches
    probe = toks[:CHUNK] + [999] * (2 * CHUNK)
    e = trie.lookup(probe)
    assert e is not None and e.n_tokens == CHUNK
    trie.release(e)
    # diverges inside chunk 0: nothing matches
    assert trie.lookup([888] * (3 * CHUNK)) is None


def test_trie_make_room_refuses_when_all_referenced():
    trie = PrefixTrie(CHUNK, 2 * ENTRY_BYTES)
    a = _toks(random.Random(0), 2, fam=0)
    b = _toks(random.Random(0), 2, fam=1)
    trie.attach(a, 2, ENTRY_BYTES, slot=0)
    trie.attach(b, 2, ENTRY_BYTES, slot=1)
    ea = trie.lookup(a + [7])
    eb = trie.lookup(b + [7])
    assert trie.make_room(ENTRY_BYTES) is None   # both held: no victim
    assert trie.bytes == 2 * ENTRY_BYTES         # trie unchanged
    trie.release(ea)
    victims = trie.make_room(ENTRY_BYTES)        # LRU: a released first but
    assert victims is not None                   # b still referenced -> a
    assert victims[0] is ea and eb in trie.entries
    trie.release(eb)


# ------------------------------------------------------- engine: token identity
def _shared_prompts(n, sys_tokens=20, lo=2, hi=10):
    """Prompts sharing one system prompt + short unique suffixes."""
    rng = np.random.default_rng(17)
    sysp = rng.integers(0, CFG.vocab_size, (sys_tokens,))
    return [np.concatenate([sysp, rng.integers(
        0, CFG.vocab_size, (int(rng.integers(lo, hi + 1)),))])
        for _ in range(n)]


@pytest.mark.parametrize("sampling", [
    None,                                                    # greedy
    SamplingParams(max_new_tokens=6, temperature=0.8, top_k=20, seed=11),
])
def test_warm_hit_token_identical_to_cold(sampling):
    """The same prompt decoded via a warm prefix hit must produce the
    exact tokens a cold engine produces — greedy and sampled (the
    counter-based PRNG keys on absolute position, not prefill shape)."""
    prompts = _shared_prompts(5)
    outs = {}
    for on in (False, True):
        eng = _engine(prefix_cache=on)
        try:
            if on:   # populate the store, then re-serve the same prompts
                eng.generate(prompts[0], sampling).result(timeout=300)
            hs = [eng.generate(p, sampling) for p in prompts]
            outs[on] = [h.result(timeout=300).tokens for h in hs]
        finally:
            eng.close()
    for cold, warm in zip(outs[False], outs[True]):
        assert (cold == warm).all()


def test_hits_counted_and_window_compile_clean():
    # sysprompt = exactly 3 chunks; every suffix keeps the prompt long
    # enough that lookup's (len-1)//chunk cap reaches the depth-3 entry
    prompts = _shared_prompts(6, sys_tokens=24, lo=2, hi=6)
    eng = _engine()
    try:
        eng.warmup()
        eng.generate(prompts[0]).result(timeout=300)  # cold miss + insert
        eng.window()                                  # measured span starts
        for h in [eng.generate(p) for p in prompts]:
            h.result(timeout=300)
        w = eng.window()
        lane = w["lanes"][32]
        assert lane["prefix_hits"] == 6               # every one a warm hit
        assert lane["prefix_misses"] == 0
        assert lane["prefix_hit_tokens"] == 6 * 24    # full sysprompt each
        assert lane["prefix_bytes"] > 0               # gauge, not diffed
        assert w["jit_compiles"] == 0                 # acceptance: clean span
        m = eng.metrics()["lanes"][32]
        assert m["prefix_misses"] == 1 and m["prefix_inserts"] >= 1
    finally:
        eng.close()


def test_tiny_budget_evicts_and_stays_correct():
    """A byte budget of exactly one entry forces LRU eviction on every new
    prefix family; counters move and outputs stay identical to cold."""
    probe = _engine()
    try:
        entry_bytes = probe._prefix_store(32).entry_bytes
    finally:
        probe.close()
    rng = np.random.default_rng(5)
    fams = [rng.integers(0, CFG.vocab_size, (20,)) for _ in range(3)]
    prompts = [np.concatenate([f, rng.integers(0, CFG.vocab_size, (4,))])
               for f in fams for _ in range(2)]
    cold = _engine(prefix_cache=False)
    try:
        want = [cold.generate(p).result(timeout=300).tokens
                for p in prompts]
    finally:
        cold.close()
    eng = _engine(prefix_cache_bytes=entry_bytes)
    try:
        got = [eng.generate(p).result(timeout=300).tokens for p in prompts]
        m = eng.metrics()["lanes"][32]
        assert m["prefix_evictions"] >= 2             # families rotate out
        assert m["prefix_bytes"] <= entry_bytes       # budget respected
        assert m["prefix_inserts"] >= 3
    finally:
        eng.close()
    for a, b in zip(want, got):
        assert (a == b).all()


# ------------------------------------------------------------- cancel safety
def test_cancel_mid_suffix_prefill_leaks_no_slot_or_ref():
    """Cancel a request while its post-hit suffix chunks are still
    filling: the lane slot, staging slot and store reference must all be
    released, and the store must keep serving hits afterwards."""
    prompts = _shared_prompts(3, sys_tokens=16, lo=12, hi=14)  # suffix > C
    eng = _engine(max_new_tokens=24, prefill_chunk=4)
    try:
        eng.generate(prompts[0]).result(timeout=300)  # insert the prefix
        blocker = eng.generate(_prompt(30))           # keeps the lane busy
        h = eng.generate(prompts[1])                  # hit + chunked suffix
        deadline = time.time() + 60
        base = eng.metrics()["prefill_chunks"]
        while eng.metrics()["prefill_chunks"] <= base:
            assert time.time() < deadline
            time.sleep(0.001)
        assert h.cancel()
        assert h.result(timeout=300).finish_reason == "cancelled"
        blocker.result(timeout=300)
        ok = eng.generate(prompts[2]).result(timeout=300)
        assert len(ok.tokens) == 24                   # slots not leaked
        store = eng._prefix_store(32)
        assert all(e.refs == 0 for e in store.trie.entries)
        pool = eng._get_pool(32)
        assert all(r is None for r in pool.request_of)
    finally:
        eng.close()


# ---------------------------------------------------------------- config gate
def test_unsupported_configs_rejected_at_init():
    with pytest.raises(ValueError, match="prefix_cache"):
        _engine(continuous=False)                     # needs the scheduler
    with pytest.raises(ValueError, match="prefix_cache"):
        _engine(prefill_chunk=None)                   # needs chunk hashing
    g2 = get_config("gemma2-27b", smoke=True)         # windowed attention:
    with pytest.raises(ValueError, match="prefix_cache"):   # unsupported
        ServingEngine(g2, init_params(g2, jax.random.PRNGKey(0)),
                      EngineConfig(mode="decoder", max_batch=2,
                                   max_new_tokens=4, pad_buckets=(32,),
                                   prefill_chunk=8, prefix_cache=True))
