"""End-to-end system behaviour: the paper's full POC loop in miniature —
train a model, deploy it in the engine, run the concurrency ladder, tabulate
the paper's metrics — plus MoE routing invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.loadtest import format_table, run_ladder
from repro.models import init_params
from repro.models.moe import moe_apply
from repro.serving import EngineConfig, ServingEngine


def test_poc_pipeline_miniature():
    """Deploy gector-small in the engine; run a reduced NS ladder (the
    paper's Fig. 7 flow); check the latency/CPU/RAM table is well-formed and
    latency grows with concurrency beyond engine capacity."""
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder", max_batch=4,
                                     pad_buckets=(32,)))
    try:
        sentences = [np.random.randint(0, cfg.vocab_size,
                                       (np.random.randint(8, 24),))
                     for _ in range(32)]
        cells = run_ladder(eng, sentences, ladder=(1, 4, 16), repeats=1)
    finally:
        eng.close()
    assert [c.ns for c in cells] == [1, 4, 16]
    for c in cells:
        assert c.latency_s > 0 and 0 <= c.vcpu_pct <= 100
        assert 0 < c.ram_pct <= 100
    # 16 concurrent on a 4-wide engine must be slower than 1
    assert cells[-1].latency_s > cells[0].latency_s
    table = format_table(cells)
    assert "latency" in table and len(table.splitlines()) == 4


def test_admission_control_improves_tail_under_overload():
    """The paper's §4 proposal, demonstrated: bounded in-flight work keeps
    served-batch latency flat; the unbounded engine degrades."""
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    sentences = [np.random.randint(0, cfg.vocab_size, (12,))
                 for _ in range(64)]

    def run(max_inflight):
        eng = ServingEngine(cfg, params,
                            EngineConfig(mode="encoder", max_batch=4,
                                         pad_buckets=(16,),
                                         max_inflight=max_inflight))
        try:
            futs = [eng.submit(s) for s in sentences[:24]]
            for f in futs:
                f.result(timeout=300)
            return eng.metrics()
        finally:
            eng.close()

    gated = run(8)
    assert gated["requests"] == 24
    assert gated["admission_peak_queue"] >= 1     # queueing engaged


# --------------------------------------------------------------- MoE props
@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 100), s=st.integers(4, 32))
def test_moe_output_finite_and_gates_normalized(seed, s):
    cfg = get_config("moonshot-v1-16b-a3b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    p = jax.tree.map(lambda x: x[0],
                     params["blocks"]["blk0"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (2, s, cfg.d_model), jnp.float32)
    out, aux = moe_apply(cfg, p, x)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    assert float(aux["load_balance_loss"]) >= 0.99  # >= 1 for any router


def test_moe_capacity_drop_is_bounded():
    """With capacity_factor >= 2 and few tokens, no token may be dropped —
    every output row must be a nonzero mixture of expert outputs."""
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(3))
    p = jax.tree.map(lambda x: x[0], params["blocks"]["blk0"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, cfg.d_model))
    out, _ = moe_apply(cfg, p, x, capacity_factor=2.0)
    row_norm = jnp.linalg.norm(out[0], axis=-1)
    assert float((row_norm == 0).mean()) == 0.0
