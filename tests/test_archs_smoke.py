"""Per-architecture smoke tests: a REDUCED same-family variant runs one
forward and one train step on CPU; output shapes + no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import decode_step, forward, init_params, make_caches
from repro.training import OptConfig, adamw_init, train_step

RNG = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16, train=False):
    kw = {}
    toks = jax.random.randint(RNG, (B, S + int(train)), 0, cfg.vocab_size)
    if cfg.enc_layers:
        kw["enc_embeds" if train else "enc_tokens_embeds"] = jnp.zeros(
            (B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        kw["prefix_embeds"] = jnp.zeros((B, cfg.vis_tokens, cfg.d_model),
                                        jnp.float32)
    return toks, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.d_model <= 512 and (cfg.moe is None
                                   or cfg.moe.num_experts <= 4)
    params = init_params(cfg, RNG)
    toks, kw = _inputs(cfg)
    logits, _, aux = forward(cfg, params, tokens=toks, **kw)
    assert logits.shape == (2, 16 + cfg.vis_tokens, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, RNG)
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params)
    toks, kw = _inputs(cfg, train=True)
    batch = {"tokens": toks, **{k: v for k, v in kw.items()}}
    params2, opt2, metrics = jax.jit(
        lambda p, o, b: train_step(cfg, oc, p, o, b))(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_with_cache(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, RNG)
    caches = make_caches(cfg, 2, 32, dtype=jnp.float32)
    toks, kw = _inputs(cfg, S=1)
    ekw = {k: v for k, v in kw.items() if k == "enc_tokens_embeds"}
    pos = jnp.zeros((2, 1), jnp.int32)
    logits, caches2, _ = decode_step(cfg, params, toks[:, :1], pos, caches,
                                     **ekw)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    # cache state changed for at least one leaf
    changed = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(caches2)))
    assert changed


def test_param_count_roughly_matches_analytic():
    for arch in ("qwen2-0.5b", "stablelm-12b"):
        cfg = get_config(arch, smoke=True)
        params = init_params(cfg, RNG)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, (arch, actual,
                                                        analytic)
