"""Occupancy-adaptive decode-segment widths (lane width tiers).

The scheduler compacts each lane's live rows into the smallest power-of-two
width tier before every decode segment (``segment_width='adaptive'``, the
default) instead of always decoding all ``max_batch`` slots. These tests
pin the tier policy, the token identity of adaptive vs fixed vs
batch-at-a-time, the compaction round-trip property (slots outside the
compact set stay bitwise untouched), and the metrics surfaces the tiers
added: per-lane ``tier_hist`` / ``compact_segments`` in ``metrics()`` and
``window()``, compile-clean windows after ``warmup()`` under both modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.loadtest import mixed_bucket_prompts
from repro.models import decode_segment, init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.kvcache import CachePool
from repro.serving.scheduler import pick_tier, width_tiers

CFG = get_config("qwen2-0.5b", smoke=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.RandomState(31)


def _engine(**kw):
    base = dict(mode="decoder", max_batch=4, max_new_tokens=6,
                pad_buckets=(16, 32), decode_segment=2)
    base.update(kw)
    return ServingEngine(CFG, PARAMS, EngineConfig(**base))


def _prompt(n):
    return RNG.randint(0, CFG.vocab_size, (n,))


# ------------------------------------------------------------- tier policy
def test_width_tiers_ladder():
    assert width_tiers(1) == (1,)
    assert width_tiers(8) == (1, 2, 4, 8)
    assert width_tiers(6) == (1, 2, 4, 6)   # max_batch always included
    with pytest.raises(ValueError):
        width_tiers(0)


def test_pick_tier_smallest_fit():
    tiers = width_tiers(8)
    assert [pick_tier(o, tiers) for o in (1, 2, 3, 4, 5, 8)] == \
        [1, 2, 4, 4, 8, 8]
    assert pick_tier(99, tiers) == 8        # clamped to the top tier


def test_segment_width_value_validated():
    with pytest.raises(ValueError, match="segment_width"):
        _engine(segment_width="auto")


# ---------------------------------------------------------- token identity
def test_adaptive_matches_fixed_and_batch_greedy():
    """Acceptance: compacting segments to occupancy tiers must not change
    a single token vs the full-width scheduler or batch-at-a-time."""
    prompts = [_prompt(n) for n in (27, 9, 14, 30)]
    outs = {}
    for name, kw in (("fixed", dict(segment_width="fixed")),
                     ("adaptive", dict(segment_width="adaptive")),
                     ("batch", dict(continuous=False))):
        eng = _engine(**kw)
        try:
            hs = [eng.generate(p) for p in prompts]
            outs[name] = [h.result(timeout=300).tokens for h in hs]
        finally:
            eng.close()
    for name in ("adaptive", "batch"):
        for a, b in zip(outs["fixed"], outs[name]):
            assert (a == b).all(), name


def test_adaptive_with_chunked_prefill_and_sampling():
    """Compaction composes with the other serving features: a chunk-
    prefilled join and a seeded sampled request produce the same tokens
    under adaptive and fixed widths (sampling is counter-based per
    (seed, position), so width must not matter)."""
    prompts = [_prompt(30), _prompt(8)]
    sampling = [SamplingParams(),
                SamplingParams(temperature=0.8, top_k=16, seed=5)]
    outs = {}
    for mode in ("fixed", "adaptive"):
        eng = _engine(prefill_chunk=8, segment_width=mode)
        try:
            hs = [eng.generate(p, s) for p, s in zip(prompts, sampling)]
            outs[mode] = [h.result(timeout=300).tokens for h in hs]
        finally:
            eng.close()
    for a, b in zip(outs["fixed"], outs["adaptive"]):
        assert (a == b).all()


# ------------------------------------------------- compaction round-trip
@settings(deadline=None, max_examples=6)
@given(mask=st.integers(1, 2 ** 4 - 1), seed=st.integers(0, 50))
def test_compact_round_trip_leaves_other_slots_untouched(mask, seed):
    """Property: compact-gather -> decode segment -> scatter-back touches
    exactly the compacted slots. Every other slot's KV stays *bitwise*
    identical (padding rows are sliced away before the scatter), and the
    pool's slot bookkeeping is not disturbed."""
    slots = [i for i in range(4) if mask >> i & 1]
    width = pick_tier(len(slots), width_tiers(4))
    pool = CachePool(CFG, 4, 24, dtype=jnp.float32)
    # randomize float leaves so "untouched" is a real statement
    leaves, treedef = jax.tree.flatten(pool.caches)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    pool.caches = jax.tree.unflatten(treedef, [
        (jax.random.normal(k, l.shape, l.dtype)
         if jnp.issubdtype(l.dtype, jnp.floating) else l)
        for k, l in zip(keys, leaves)])
    before = [np.asarray(x) for x in jax.tree.leaves(pool.caches)]
    lengths_before = list(pool.lengths)
    occ = len(slots)
    idx, view = pool.compact_view(slots, width)
    assert idx[:occ] == slots and len(idx) == width
    _, _, _, out = decode_segment(
        CFG, PARAMS, jnp.zeros((width, 1), jnp.int32),
        jnp.full((width, 1), 3, jnp.int32), view, n_steps=2,
        active=jnp.arange(width) < occ,
        budget=jnp.full((width,), 5, jnp.int32))
    pool.scatter_back(slots, out)
    after = [np.asarray(x) for x in jax.tree.leaves(pool.caches)]
    others = [i for i in range(4) if i not in slots]
    changed = False
    for b, a in zip(before, after):
        assert (b[:, others] == a[:, others]).all()
        if not np.array_equal(b[:, slots], a[:, slots]):
            changed = True
    assert changed                  # the live slots actually decoded
    assert pool.lengths == lengths_before
    assert pool.request_of == [None] * 4


def test_compact_view_rejects_overfull():
    pool = CachePool(CFG, 4, 24, dtype=jnp.float32)
    with pytest.raises(ValueError, match="width"):
        pool.compact_view([0, 1, 2], 2)
    with pytest.raises(ValueError, match="width"):
        pool.compact_view([], 2)


# --------------------------------------------------------- metrics surfaces
def test_tier_hist_adaptive_lone_request_compacts():
    """A lone request must decode at tier 1, never width max_batch — the
    tentpole behavior — and the lane counters must say so."""
    eng = _engine()
    try:
        eng.generate(_prompt(8)).result(timeout=300)
        lanes = eng.metrics()["lanes"]
        stat = lanes[16]
        assert stat["decode_segments"] >= 1
        assert stat["tier_hist"] == {1: stat["decode_segments"]}
        assert stat["compact_segments"] == stat["decode_segments"]
        assert lanes[32]["tier_hist"] == {}
    finally:
        eng.close()


def test_tier_hist_fixed_mode_always_max_batch():
    eng = _engine(segment_width="fixed")
    try:
        eng.generate(_prompt(8)).result(timeout=300)
        stat = eng.metrics()["lanes"][16]
        assert stat["tier_hist"] == {4: stat["decode_segments"]}
        assert stat["compact_segments"] == 0
    finally:
        eng.close()


@pytest.mark.parametrize("mode", ["adaptive", "fixed"])
def test_window_tier_hist_and_compile_clean(mode):
    """window() must diff the tier histogram / compaction counters per
    span, and a warmed engine must serve mixed-bucket traffic without a
    single jit compile — under both segment_width modes."""
    eng = _engine(prefill_chunk=8, segment_width=mode)
    try:
        eng.warmup()
        eng.window()                                  # reset the window
        prompts = mixed_bucket_prompts((16, 32), 6, CFG.vocab_size,
                                       rng_seed=3)
        hs = [eng.generate(p) for p in prompts]
        for h in hs:
            h.result(timeout=300)
        w = eng.window()
        assert w["requests"] == 6
        assert w["jit_compiles"] == 0                 # compile-clean span
        for bucket in (16, 32):
            stat = w["lanes"][bucket]
            assert stat["decode_segments"] >= 1
            assert sum(stat["tier_hist"].values()) == \
                stat["decode_segments"]
            if mode == "fixed":
                assert set(stat["tier_hist"]) == {4}
                assert stat["compact_segments"] == 0
            else:
                assert stat["compact_segments"] == sum(
                    c for t, c in stat["tier_hist"].items() if t < 4)
        # a second window diffs the histogram away
        eng.generate(_prompt(8)).result(timeout=300)
        w2 = eng.window()
        assert w2["lanes"][32]["tier_hist"] == {}
        assert sum(w2["lanes"][16]["tier_hist"].values()) == \
            w2["lanes"][16]["decode_segments"]
        # cumulative metrics keep the full histogram
        m = eng.metrics()["lanes"][16]
        assert sum(m["tier_hist"].values()) == m["decode_segments"]
    finally:
        eng.close()


def test_adaptive_segments_track_occupancy_under_concurrency():
    """Two concurrent requests in one lane run width-2 tiers while both
    are live, width-1 after one retires — the histogram records the mix
    (and batch_sizes keeps reporting true occupancy, not tier width)."""
    eng = _engine(max_new_tokens=12, pad_buckets=(16,))
    try:
        eng.warmup(batch_sizes=[1, 2])
        h1 = eng.generate(_prompt(6))                 # 12-token decode
        next(iter(h1))                                # decode underway
        h2 = eng.generate(_prompt(7), SamplingParams(max_new_tokens=2))
        h1.result(timeout=300)
        h2.result(timeout=300)
        hist = eng.metrics()["lanes"][16]["tier_hist"]
        assert hist.get(2, 0) >= 1                    # co-resident span
        assert hist.get(1, 0) >= 1                    # lone-tail span
        assert max(eng.batch_sizes) <= 2
    finally:
        eng.close()
