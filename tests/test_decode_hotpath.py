"""Decode hot path (PR: block-skipping flash attention, fused scan decode,
pooled KV caches): kernel skipping is exact and actually skips, the fused
scan path is token-identical to the seed's per-token loop, pool slots don't
leak state, and the engine rejects instead of truncating."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import (flash_attention,
                                           live_block_counts,
                                           n_visited_blocks)
from repro.kernels.ref import decode_attention_ref, flash_attention_ref
from repro.models import init_params
from repro.serving import (CachePool, EngineConfig, RequestTooLong,
                           ServingEngine)

R = jax.random.PRNGKey


# ------------------------------------------------- block-skipping kernels
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 64, None),          # window aligned to bk
    (True, 40, None),          # window NOT aligned to bk (partial blocks)
    (True, 100, 30.0),         # non-aligned + softcap
    (False, None, None),
    (False, 96, None),         # windowed non-causal: lo-skip only
])
def test_flash_block_skipping_matches_ref(causal, window, softcap):
    S, bq, bk = 256, 64, 64
    q = jax.random.normal(R(0), (4, S, 32), jnp.float32)
    k = jax.random.normal(R(1), (2, S, 32), jnp.float32)
    v = jax.random.normal(R(2), (2, S, 32), jnp.float32)
    out, vis = flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, bq=bq, bk=bk,
                               return_visits=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # the kernel's runtime visit counter must equal the analytic live range
    exp = live_block_counts(S, S, causal=causal, window=window, bq=bq, bk=bk)
    assert (np.asarray(vis) == np.array(exp)[None, :]).all()


def test_flash_causal_visits_about_half():
    """Acceptance: causal flash attention scores ~half the KV blocks the
    seed's full sweep visited."""
    S, bq, bk = 512, 64, 64
    q = jax.random.normal(R(0), (2, S, 32), jnp.float32)
    k = jax.random.normal(R(1), (2, S, 32), jnp.float32)
    v = jax.random.normal(R(2), (2, S, 32), jnp.float32)
    _, vis = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                             return_visits=True)
    total = (S // bq) * (S // bk)          # what the seed always visited
    visited = int(np.asarray(vis)[0].sum())
    assert visited == total * (1 + S // bk) / (2 * S // bk)  # 36 of 64
    assert visited <= 0.6 * total


def test_flash_windowed_grid_shrinks():
    """Causal+windowed attention shrinks the kv grid axis itself to
    O(window/bk) — dead blocks are not even iterated."""
    S, bq, bk, window = 512, 64, 64, 64
    assert n_visited_blocks(causal=True, window=window, bq=bq, bk=bk,
                            n_kv=S // bk) == 3
    assert n_visited_blocks(causal=True, window=None, bq=bq, bk=bk,
                            n_kv=S // bk) == S // bk


def test_decode_attention_early_out():
    """A short request in a long ring buffer only pays for the live blocks;
    sliding windows bound the sweep regardless of cache length."""
    BHkv, G, D, L, bk = 4, 2, 32, 256, 64
    q = jax.random.normal(R(0), (BHkv, G, D), jnp.float32)
    k = jax.random.normal(R(1), (BHkv, L, D), jnp.float32)
    v = jax.random.normal(R(2), (BHkv, L, D), jnp.float32)
    for valid, window, want in [(17, None, 1), (120, None, 2),
                                (256, None, 4), (120, 40, 1)]:
        kv_pos = jnp.where(jnp.arange(L)[None, :] < valid,
                           jnp.arange(L)[None, :], -1).astype(jnp.int32)
        kv_pos = jnp.broadcast_to(kv_pos, (BHkv, L))
        q_pos = jnp.full((BHkv, 1), valid - 1, jnp.int32)
        out, vis = decode_attention(q, k, v, q_pos, kv_pos, window=window,
                                    bk=bk, return_visits=True)
        ref = decode_attention_ref(q, k, v, q_pos[:, 0], kv_pos,
                                   window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
        assert (np.asarray(vis) == want).all()


def test_attn_block_size_heuristic_and_override():
    # heuristic: blocks shrink toward the sequence / window
    assert ops.attn_block_sizes("prefill", 2048, 2048) == (128, 128)
    assert ops.attn_block_sizes("prefill", 30, 30) == (32, 32)
    bq, bk = ops.attn_block_sizes("prefill", 2048, 2048, window=40)
    assert bk == 64
    assert ops.attn_block_sizes("decode", 1, 48)[1] == 64
    # a registered (autotuned) entry wins over the heuristic
    ops.register_attn_block_sizes("prefill", 2048, 2048, None, 32, 16)
    try:
        assert ops.attn_block_sizes("prefill", 2048, 2048) == (32, 16)
    finally:
        ops._ATTN_BLOCK_TABLE.clear()
    # heuristic block sizes stay correct through the padded ops wrapper
    B, S, H, D = 1, 200, 4, 16
    q = jax.random.normal(R(3), (B, S, H, D), jnp.float32)
    k = jax.random.normal(R(4), (B, S, 2, D), jnp.float32)
    v = jax.random.normal(R(5), (B, S, 2, D), jnp.float32)
    out = ops.mha_prefill(q, k, v, window=40)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        k.transpose(0, 2, 1, 3).reshape(B * 2, S, D),
        v.transpose(0, 2, 1, 3).reshape(B * 2, S, D),
        window=40).reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal,window", [(False, None), (False, 96),
                                           (True, None)])
def test_mha_prefill_padded_kv_masked(causal, window):
    """Padded KV columns must never receive softmax mass — the causal mask
    alone does not hide them when causal=False (kv_len masking in the
    kernel does)."""
    B, S, H, D = 1, 200, 4, 16          # pads to a block multiple
    q = jax.random.normal(R(0), (B, S, H, D), jnp.float32)
    k = jax.random.normal(R(1), (B, S, 2, D), jnp.float32)
    v = jax.random.normal(R(2), (B, S, 2, D), jnp.float32)
    out = ops.mha_prefill(q, k, v, causal=causal, window=window)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        k.transpose(0, 2, 1, 3).reshape(B * 2, S, D),
        v.transpose(0, 2, 1, 3).reshape(B * 2, S, D),
        causal=causal, window=window).reshape(B, H, S, D).transpose(
            0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------ fused scan decode
def test_scan_decode_matches_seed_loop():
    """The fused prefill+scan path must produce token-for-token identical
    output to the seed's per-token Python loop (use_scan_decode=False
    reproduces the seed structure exactly, scanned periods included)."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, (rng.randint(3, 12),))
               for _ in range(3)]
    outs = {}
    for scan, pool in [(False, False), (True, True)]:
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=4, max_new_tokens=3,
            pad_buckets=(16,), use_scan_decode=scan, use_cache_pool=pool))
        try:
            futs = [eng.submit(p) for p in prompts]
            outs[scan] = np.stack([f.result(timeout=300) for f in futs])
        finally:
            eng.close()
    assert (outs[False] == outs[True]).all()


# ----------------------------------------------------------- cache pool
def test_cache_pool_acquire_resets_and_isolates():
    cfg = get_config("qwen2-0.5b", smoke=True)
    pool = CachePool(cfg, n_slots=4, max_len=16, dtype=jnp.float32)
    slots, view = pool.acquire(["a", "b"])
    assert len(slots) == 2 and pool.free_slots == 2
    # dirty everything, release, re-acquire: slots must come back clean
    pool.caches = jax.tree.map(lambda x: x + 1, pool.caches)
    pool.release_many(slots)
    slots2, view2 = pool.acquire(["c", "d", "e"])
    assert pool.free_slots == 1
    pos = np.asarray(view2["blk0"]["pos"])
    assert (pos == -1).all()                   # sentinel restored
    assert (np.asarray(view2["blk0"]["k"]) == 0).all()
    # unassigned slot keeps its (dirty) state — reset is per-assignment
    spare = [i for i in range(4) if i not in slots2][0]
    assert (np.asarray(pool.caches["blk0"]["pos"][:, spare]) == 0).all()


def test_cache_pool_write_back_persists():
    cfg = get_config("qwen2-0.5b", smoke=True)
    pool = CachePool(cfg, n_slots=4, max_len=16, dtype=jnp.float32)
    slots, view = pool.acquire(["a", "b"])
    view = jax.tree.map(lambda x: x + 2, view)
    pool.write_back(slots, view, lengths=[5, 7])
    got = np.asarray(pool.caches["blk0"]["pos"][:, slots])
    assert (got == 1).all()                    # -1 + 2
    assert pool.lengths[slots[0]] == 5 and pool.lengths[slots[1]] == 7


# ------------------------------------------------------- engine behaviour
def test_engine_rejects_too_long_requests():
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        mode="encoder", max_batch=4, pad_buckets=(16, 32)))
    try:
        fut = eng.submit(np.zeros(33, np.int32))    # > largest bucket
        with pytest.raises(RequestTooLong):
            fut.result(timeout=30)
        ok = eng.submit(np.zeros(20, np.int32))     # still serves valid ones
        assert ok.result(timeout=120).shape[0] == 32
    finally:
        eng.close()


def test_admission_no_thread_per_request_and_nonblocking_submit():
    """Admission control must not spawn a dispatcher thread per request,
    and a saturated engine must not block submit() — excess requests park
    on the overflow queue whose true depth shows up in the stats."""
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        mode="encoder", max_batch=4, pad_buckets=(32,), max_inflight=2))
    try:
        fut = eng.submit(np.zeros(8, np.int32))     # warm the compile cache
        fut.result(timeout=120)
        base = threading.active_count()
        peak = base
        futs = []
        t0 = time.perf_counter()
        for _ in range(8):
            futs.append(eng.submit(np.zeros(8, np.int32)))
            peak = max(peak, threading.active_count())
        submit_wall = time.perf_counter() - t0      # all 8 fired at once
        for f in futs:
            f.result(timeout=120)
        assert peak <= base                         # no per-request threads
        assert submit_wall < 1.0                    # submit never blocked
        m = eng.metrics()
        assert m["requests"] == 9
        assert m["admission_peak_queue"] >= 2       # true overflow depth
    finally:
        eng.close()
