import os
import sys

# tests must see ONE device (dry-run owns the 512-device trick)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
