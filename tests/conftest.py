import os
import sys

# tests must see ONE device (dry-run owns the 512-device trick)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# hypothesis is not installable in the offline test environment; fall back
# to the deterministic shim so the property tests still collect and run.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_shim
    _hypothesis_shim.install()
