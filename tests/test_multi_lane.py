"""Multi-lane continuous scheduling + chunked prefill, and the serving-path
bugfix sweep that rode along: cross-bucket joins admit without waiting for
another bucket's set to drain, chunked prefill is token-identical to
whole-prompt prefill, empty prompts are rejected through the handle, warmup
primes every bucket (no compiles in the measured window), the admission
queue depth ignores cancelled parked requests, and run_ladder's warmup
clears the phase-timing samples it used to leak into metrics()."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.loadtest import mixed_bucket_prompts, run_ladder
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine

CFG = get_config("qwen2-0.5b", smoke=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RNG = np.random.RandomState(23)


def _engine(**kw):
    base = dict(mode="decoder", max_batch=4, max_new_tokens=6,
                pad_buckets=(16, 32), decode_segment=2)
    base.update(kw)
    return ServingEngine(CFG, PARAMS, EngineConfig(**base))


def _prompt(n):
    return RNG.randint(0, CFG.vocab_size, (n,))


# ------------------------------------------------------- cross-bucket lanes
def test_cross_bucket_join_admits_without_waiting_for_drain():
    """A bucket-32 request arriving while the bucket-16 set decodes must
    admit into its own lane immediately — with lanes it finishes while the
    bucket-16 request is still in flight; the legacy single-set gate makes
    it wait for the full drain."""
    eng = _engine(max_new_tokens=48)
    try:
        eng.warmup(batch_sizes=[1])
        h1 = eng.generate(_prompt(8))                 # bucket 16, long
        next(iter(h1))                                # decode underway
        h2 = eng.generate(_prompt(24),                # bucket 32, short
                          SamplingParams(max_new_tokens=2))
        h2.result(timeout=300)
        assert not h1.done()      # b16 still decoding: no head-of-line wait
        h1.result(timeout=300)
        lanes = eng.metrics()["lanes"]
        assert lanes[16]["decode_segments"] > 0
        assert lanes[32]["decode_segments"] > 0
        assert lanes[32]["joins"] >= 1                # mid-flight, own lane
    finally:
        eng.close()


def test_single_set_gate_recreates_head_of_line_wait():
    eng = _engine(max_new_tokens=48, multi_lane=False)
    try:
        eng.warmup(batch_sizes=[1])
        h1 = eng.generate(_prompt(8))
        next(iter(h1))
        h2 = eng.generate(_prompt(24), SamplingParams(max_new_tokens=2))
        h2.result(timeout=300)
        assert h1.done()          # b32 had to wait for the b16 drain
    finally:
        eng.close()


def test_lanes_match_batch_at_a_time_greedy_across_buckets():
    """Acceptance: greedy outputs stay token-identical to batch-at-a-time
    across buckets, with and without chunked prefill."""
    prompts = [_prompt(n) for n in (27, 9, 14, 30)]
    outs = {}
    for name, kw in (("batch", dict(continuous=False)),
                     ("lanes", dict()),
                     ("chunked", dict(prefill_chunk=8))):
        eng = _engine(**kw)
        try:
            hs = [eng.generate(p) for p in prompts]
            outs[name] = [h.result(timeout=300).tokens for h in hs]
        finally:
            eng.close()
    for name in ("lanes", "chunked"):
        for a, b in zip(outs["batch"], outs[name]):
            assert (a == b).all(), name


# ---------------------------------------------------------- chunked prefill
def test_chunked_prefill_token_identical_and_counted():
    prompts = [_prompt(n) for n in (28, 20, 9)]
    outs = {}
    for chunk in (None, 8):
        eng = _engine(prefill_chunk=chunk)
        try:
            hs = [eng.generate(p) for p in prompts]
            outs[chunk] = [h.result(timeout=300).tokens for h in hs]
            if chunk is not None:
                m = eng.metrics()
                # 28 -> 4 chunks, 20 -> 3, 9 -> 2
                assert m["prefill_chunks"] >= 9
                assert m["lanes"][32]["prefill_chunks"] >= 7
        finally:
            eng.close()
    for a, b in zip(outs[None], outs[8]):
        assert (a == b).all()


def test_chunked_prefill_interleaves_with_inflight_decode():
    """A long-prompt join must not stall the in-flight row for its whole
    prefill: its chunks interleave with decode segments, and both requests
    finish correct lengths."""
    eng = _engine(max_new_tokens=24, prefill_chunk=4)
    try:
        eng.generate(_prompt(5)).result(timeout=300)  # warm the compiles
        h1 = eng.generate(_prompt(5))                 # bucket 16, decoding
        next(iter(h1))
        h2 = eng.generate(_prompt(30))                # 8 chunks of 4
        r1, r2 = h1.result(timeout=300), h2.result(timeout=300)
        assert len(r1.tokens) == 24 and len(r2.tokens) == 24
        m = eng.metrics()
        assert m["prefill_chunks"] >= 8
        assert m["lanes"][32]["joins"] >= 1
    finally:
        eng.close()


def test_cancel_mid_chunked_prefill_resolves_cancelled():
    eng = _engine(max_new_tokens=24, prefill_chunk=4)
    try:
        eng.generate(_prompt(4)).result(timeout=300)  # warm compiles
        blocker = eng.generate(_prompt(4))            # whole-prefill path:
        h = eng.generate(_prompt(30))                 # only h chunks (8x4)
        deadline = time.time() + 60                   # fill underway
        while eng.metrics()["prefill_chunks"] < 1:
            assert time.time() < deadline
            time.sleep(0.001)
        assert h.cancel()
        res = h.result(timeout=300)
        assert res.finish_reason == "cancelled"
        blocker.result(timeout=300)
        ok = eng.generate(_prompt(30)).result(timeout=300)
        assert len(ok.tokens) == 24                   # slots not leaked
    finally:
        eng.close()


def test_prefill_chunk_ring_overflow_rejected_at_init():
    """A chunk size whose padded round-up exceeds the slot's KV length
    would wrap the ring and overwrite the prompt prefix — the engine must
    refuse the config instead of corrupting silently."""
    with pytest.raises(ValueError, match="prefill_chunk"):
        _engine(pad_buckets=(32,), prefill_chunk=12, max_new_tokens=2)
    with pytest.raises(ValueError, match="prefill_chunk"):
        _engine(prefill_chunk=0)
    _engine(pad_buckets=(32,), prefill_chunk=8, max_new_tokens=2).close()


# --------------------------------------------------------- bugfix satellites
def test_empty_prompt_rejected_through_handle():
    eng = _engine()
    try:
        h = eng.generate(np.zeros(0, np.int32))
        with pytest.raises(ValueError, match="non-empty"):
            h.result(timeout=10)
        with pytest.raises(ValueError):
            eng.submit(np.zeros(0, np.int32)).result(timeout=10)
        assert eng.generate(_prompt(4)).result(timeout=300) is not None
    finally:
        eng.close()


def test_warmup_primes_all_buckets_no_compiles_in_window():
    eng = _engine(prefill_chunk=8)
    try:
        eng.warmup()
        eng.window()                                  # reset the window
        prompts = mixed_bucket_prompts((16, 32), 6, CFG.vocab_size,
                                       rng_seed=3)
        hs = [eng.generate(p) for p in prompts]
        for h in hs:
            h.result(timeout=300)
        w = eng.window()
        assert w["requests"] == 6
        assert w["jit_compiles"] == 0                 # compile-clean span
    finally:
        eng.close()


def test_warmup_primes_buckets_batch_at_a_time():
    eng = _engine(continuous=False)
    try:
        eng.warmup(batch_sizes=[1, 2])
        n = eng._jit_compiles()
        hs = [eng.generate(_prompt(k)) for k in (8, 24)]
        for h in hs:
            h.result(timeout=300)
        assert eng._jit_compiles() == n               # both buckets primed
    finally:
        eng.close()


def test_admission_peak_queue_ignores_cancelled_parked():
    eng = _engine(max_inflight=1, max_new_tokens=24, pad_buckets=(16,))
    try:
        eng.generate(_prompt(4)).result(timeout=300)  # warm compiles
        a = eng.generate(_prompt(4))                  # holds the one slot
        b = eng.generate(_prompt(4))                  # parked (depth 1)
        assert b.cancel()
        c = eng.generate(_prompt(4))                  # parked; b is phantom
        d = eng.generate(_prompt(4))                  # parked (depth 2)
        for h in (a, c, d):
            h.result(timeout=300)
        assert eng.metrics()["admission_peak_queue"] == 2
    finally:
        eng.close()


def test_run_ladder_warmup_clears_phase_timings():
    eng = _engine(pad_buckets=(16,))
    try:
        sents = [_prompt(6) for _ in range(4)]
        run_ladder(eng, sents, ladder=(2,), repeats=1, warmup=True)
        # only the 2 measured requests contribute phase timings — the
        # compile-laden warmup request must not leak into the means
        assert len(eng.timings) == 2
        assert eng.metrics()["requests"] == 2
    finally:
        eng.close()


def test_lane_counters_window_diff():
    eng = _engine()
    try:
        eng.generate(_prompt(8)).result(timeout=300)
        w1 = eng.window()
        assert w1["lanes"][16]["decode_segments"] >= 1
        eng.generate(_prompt(24)).result(timeout=300)
        w2 = eng.window()
        assert w2["lanes"][16]["decode_segments"] == 0   # diffed away
        assert w2["lanes"][32]["decode_segments"] >= 1
        assert eng.metrics()["lanes"][16]["decode_segments"] >= 1
    finally:
        eng.close()
