"""Deployment lab: profiles as single source of truth, telemetry
summaries, experiment-record schema, measured-cost math, engine metric
windows, and the smoke grid + drift report end to end."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import costmodel, environments
from repro.deploy import costs, profiles, report, runner, telemetry
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine


# ---------------------------------------------------------------- profiles
def test_profiles_are_the_single_source_of_truth():
    """core.environments must re-export deploy.profiles records verbatim —
    the spec/price duplication this PR removed must not come back."""
    assert environments.Instance is profiles.EnvironmentProfile
    assert list(environments.INSTANCES) == list(profiles.PROFILES)
    for p in profiles.PROFILES:
        assert environments.instance(p.provider, p.machine) is p
    # the static cost model prices through the same records
    assert environments.NS_LADDER == profiles.NS_LADDER
    assert environments.LATENCY_SLO_S == profiles.LATENCY_SLO_S
    assert environments.PROVIDERS == profiles.PROVIDERS
    assert environments.MACHINES == profiles.MACHINES


def test_profile_pricing_and_lookup():
    p = profiles.profile("AWS", "C")
    assert p.key == "AWS/C" and not p.is_gpu
    assert p.hourly_cost_usd == pytest.approx(
        p.monthly_cost_usd / profiles.HOURS_PER_MONTH)
    assert profiles.profile_by_key("Azure/G").is_gpu
    assert len(profiles.paper_profiles()) == 21
    assert all(q.provider in profiles.PROVIDERS
               for q in profiles.paper_profiles())
    with pytest.raises(KeyError):
        profiles.profile("AWS", "Z")
    d = p.spec_dict()
    assert d["hourly_cost_usd"] == p.hourly_cost_usd


def test_costmodel_consistent_with_profile_hourly_price():
    """$/1M from the static cost model == profile hourly price applied to
    the paper's best SLO throughput (the consistency the refactor must
    preserve)."""
    cpm = costmodel.cost_per_million_sentences()
    for prov in ("AWS", "GCP", "Azure"):
        for m in "ABCDEFG":
            ns = costmodel.max_ns_within_slo(prov, m)
            if ns == 0:
                assert cpm[prov][m] == float("inf")
                continue
            lat = environments.MEASURED[prov][m][ns][0]
            expect = costs.usd_per_million_sentences(
                ns / lat, profiles.profile(prov, m).hourly_cost_usd)
            assert cpm[prov][m] == pytest.approx(expect, rel=1e-9)


# --------------------------------------------------------------- telemetry
def _tick(t, cpu, ram, cores=(), pgf=None):
    return telemetry.TelemetrySample(t_s=t, cpu_pct=cpu, per_core_pct=cores,
                                     ram_pct=ram, pgfaults_per_s=pgf)


def test_timeline_summary_percentiles_synthetic():
    tl = telemetry.TelemetryTimeline(tuple(
        _tick(i * 0.1, float(i), 50.0 + i, cores=(float(i), 4 * float(i)))
        for i in range(11)))                     # cpu 0..10, ram 50..60
    s = tl.summary()
    assert s["n_samples"] == 11
    assert s["duration_s"] == pytest.approx(1.0)
    assert s["cpu_pct"]["mean"] == pytest.approx(5.0)
    assert s["cpu_pct"]["p50"] == pytest.approx(5.0)
    assert s["cpu_pct"]["p95"] == pytest.approx(9.5)
    assert s["cpu_pct"]["max"] == pytest.approx(10.0)
    assert s["ram_spread_pct"] == pytest.approx(10.0)
    assert s["core_count"] == 2
    # core1 mean = 20, aggregate mean = 5 -> imbalance 15
    assert s["hottest_core_mean_pct"] == pytest.approx(20.0)
    assert s["core_imbalance_pct"] == pytest.approx(15.0)


def test_timeline_summary_handles_absent_series():
    tl = telemetry.TelemetryTimeline(tuple(
        _tick(i * 0.1, None, None) for i in range(3)))
    s = tl.summary()
    assert s["cpu_pct"] is None and s["ram_pct"] is None
    assert "ram_spread_pct" not in s
    empty = telemetry.TelemetryTimeline(())
    assert empty.summary()["n_samples"] == 0


def test_sampler_windows_and_compat_shim():
    import time
    with telemetry.HardwareSampler(period_s=0.02) as hw:
        time.sleep(0.15)
        hw.mark()
        first = hw.sample_now()
        w = hw.window()
    assert first is not None
    assert len(w) >= 1                       # sample_now guarantees one
    assert all(s.t_s >= 0 for s in w.samples)
    # the loadtest-facing shim still exposes .samples / .mean
    cs = telemetry.CpuSampler(period_s=0.02)
    with cs:
        time.sleep(0.1)
    assert isinstance(cs.mean, float)
    assert all(isinstance(v, float) for v in cs.samples)


def test_loadtest_imports_telemetry_back():
    """No duplicated /proc parsing: loadtest's sampler IS telemetry's."""
    from repro.core import loadtest
    assert loadtest.CpuSampler is telemetry.CpuSampler
    assert loadtest.read_ram_pct is telemetry.read_ram_pct


# ------------------------------------------------------------ cost algebra
def test_measured_cost_math_known_numbers():
    # 10 sentences/s at $0.36/h -> $1e-4/s / 10 per sentence = $1e-5
    # -> $10 per 1M sentences
    assert costs.usd_per_million_sentences(10.0, 0.36) == pytest.approx(10.0)
    assert costs.usd_per_million_sentences(0.0, 1.0) == float("inf")


def _fake_record(provider, machine, cells, kind="closed_ladder",
                 host="h1"):
    p = profiles.profile(provider, machine)
    return {"schema_version": 1, "profile": p.spec_dict(),
            "scenario": {"name": "t", "kind": kind, "mode": "encoder",
                         "repeats": 1},
            "engine": {"mode": "encoder"}, "cells": cells,
            "telemetry": {"ram_spread_pct": 1.0}, "engine_window": {},
            "wall_s": 1.0, "host": {"id": host}, "created_unix": 0.0}


def _cell(ns, latency_s):
    return {"ns": ns, "latency_s": latency_s, "latency_p95_s": latency_s,
            "vcpu_pct": 50.0, "ram_pct": 40.0, "repeats": 1,
            "sentences_per_s": ns / latency_s}


def test_measured_cost_table_and_cheapest():
    recs = [
        _fake_record("AWS", "C", [_cell(1, 0.2), _cell(4, 0.4),
                                  _cell(16, 4.0)]),     # best SLO: ns=4
        _fake_record("AWS", "G", [_cell(1, 0.05), _cell(4, 0.1),
                                  _cell(16, 0.4)]),     # meets SLO at 16
    ]
    table = costs.measured_cost_table(recs)
    c = profiles.profile("AWS", "C")
    assert table["AWS/C"]["best_ns"] == 4               # 10/s beats 5/s
    assert table["AWS/C"]["usd_per_1m_sentences"] == pytest.approx(
        costs.usd_per_million_sentences(10.0, c.hourly_cost_usd))
    assert costs.measured_max_ns_within_slo(recs[0]["cells"]) == 4
    # both meet SLO at ns>=4; C is cheaper per hour
    assert costs.cheapest_slo_compliant(recs, target_ns=4) == "AWS/C"
    # only G survives at ns>=16
    assert costs.cheapest_slo_compliant(recs, target_ns=16) == "AWS/G"
    prem = costs.gpu_vs_cpu_premium(recs)
    g = profiles.profile("AWS", "G")
    assert prem["price_ratio"] == pytest.approx(
        g.hourly_cost_usd / c.hourly_cost_usd)
    assert prem["n_cpu_profiles"] == 1 and prem["n_gpu_profiles"] == 1
    assert prem["cost_per_sentence_ratio"] is not None


def test_profile_never_meeting_slo_priced_infinite():
    recs = [_fake_record("AWS", "A", [_cell(1, 5.0)])]
    table = costs.measured_cost_table(recs)
    assert table["AWS/A"]["usd_per_1m_sentences"] == float("inf")
    assert table["AWS/A"]["best_ns"] is None
    assert costs.cheapest_slo_compliant(recs, target_ns=1) is None


# ----------------------------------------------------------- engine window
def test_engine_window_attributes_counters_to_spans():
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder", max_batch=4,
                                     pad_buckets=(32,)))
    try:
        sents = [np.random.randint(0, cfg.vocab_size, (12,))
                 for _ in range(8)]
        for s in sents[:3]:
            eng.submit(s).result(timeout=300)
        w1 = eng.window()
        assert w1["requests"] == 3
        assert w1["latency_p95_s"] is not None
        for s in sents[3:8]:
            eng.submit(s).result(timeout=300)
        w2 = eng.window()
        assert w2["requests"] == 5                  # only the new span
        assert eng.metrics()["requests"] == 8       # cumulative unchanged
        w3 = eng.window()
        assert w3["requests"] == 0
        assert w3["latency_p95_s"] is None          # never fabricated
    finally:
        eng.close()


def test_engine_window_diffs_continuous_counters():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        mode="decoder", max_batch=2, max_new_tokens=4, pad_buckets=(16,),
        decode_segment=2))
    try:
        eng.generate(np.arange(5) % cfg.vocab_size).result(timeout=600)
        w1 = eng.window()
        assert w1["decode_segments"] >= 1
        assert w1["prefill_batches"] >= 1
        w2 = eng.window()
        assert w2["decode_segments"] == 0           # counters diffed
        assert w2["prefill_batches"] == 0
        # cumulative metrics still carry the totals
        assert eng.metrics()["decode_segments"] >= w1["decode_segments"]
    finally:
        eng.close()


# ---------------------------------------------------- staggered phase split
def test_staggered_result_surfaces_timing_split():
    from repro.core.loadtest import run_staggered
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        mode="decoder", max_batch=2, max_new_tokens=4, pad_buckets=(16,)))
    try:
        prompts = [np.arange(4 + i) % cfg.vocab_size for i in range(3)]
        r = run_staggered(eng, prompts, gap_s=0.01,
                          sampling=SamplingParams(max_new_tokens=2))
    finally:
        eng.close()
    assert r.n_requests == 3
    assert r.queue_mean_s >= 0 and r.prefill_mean_s >= 0
    assert r.decode_mean_s >= 0 and r.queue_p95_s >= r.queue_mean_s * 0.0
    # split must be consistent with the end-to-end percentiles it refines
    assert (r.queue_mean_s + r.prefill_mean_s + r.decode_mean_s
            <= r.latency_p95_s * 3 + 1e-6)


# ------------------------------------------------------- grid + drift smoke
def _encoder_factory(scenario):
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder", max_batch=4,
                                     pad_buckets=(32,)))
    eng.warmup()          # the public compile-priming entry point
    rng = np.random.RandomState(0)
    sents = [rng.randint(0, cfg.vocab_size, (16,)) for _ in range(32)]
    return eng, sents, None


def test_engine_summary_reflects_quant_knobs():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    base = ServingEngine(cfg, params, EngineConfig(
        mode="decoder", max_batch=2, pad_buckets=(16,)))
    quant = ServingEngine(cfg, params, EngineConfig(
        mode="decoder", max_batch=2, pad_buckets=(16,),
        weight_quant="int8", kv_quant="int8"))
    try:
        b = runner._engine_summary(base)
        q = runner._engine_summary(quant)
        assert (b["weight_quant"], b["kv_quant"]) == (None, None)
        assert (q["weight_quant"], q["kv_quant"]) == ("int8", "int8")
        assert 0 < q["weight_bytes"] < b["weight_bytes"]
        json.dumps(q)                             # record stays JSONL-able
    finally:
        base.close()
        quant.close()


def test_smoke_grid_records_schema_and_drift_report(tmp_path):
    scenario = runner.WorkloadScenario(name="smoke", ladder=(1, 2),
                                       repeats=1)
    grid = runner.ExperimentRunner(_encoder_factory)
    out = tmp_path / "grid.jsonl"
    records = grid.run_grid(list(runner.smoke_grid_profiles()), [scenario],
                            out_path=str(out))
    assert len(records) == 2                      # 2 profiles x 1 scenario

    # --- JSONL round-trip + schema -----------------------------------
    rows = runner.read_jsonl(str(out))
    assert len(rows) == 2
    for row in rows:
        assert set(runner.RECORD_FIELDS) <= set(row)
        assert row["schema_version"] == runner.SCHEMA_VERSION
        assert row["scenario"]["kind"] == "closed_ladder"
        assert [c["ns"] for c in row["cells"]] == [1, 2]
        for c in row["cells"]:
            assert c["latency_s"] > 0
            assert c["sentences_per_s"] == pytest.approx(
                c["ns"] / c["latency_s"])
        assert row["telemetry"]["n_samples"] >= 1
        assert "requests" in row["engine_window"]
        assert row["engine_window"]["requests"] >= scenario.repeats
        # v2 schema: the engine dict always carries the quant knobs (None
        # on the default path) + resident weight bytes
        assert {"weight_quant", "kv_quant", "weight_bytes"} <= set(
            row["engine"])
        assert row["engine"]["weight_quant"] is None
        assert row["engine"]["kv_quant"] is None
        assert row["engine"]["weight_bytes"] > 0
        json.dumps(row)                           # JSON-serializable

    # --- drift report ------------------------------------------------
    rep = report.drift_report(rows)
    assert rep["n_records"] == 2
    assert rep["profiles"] == ["AWS/C", "AWS/G"]
    # every paper finding is listed with its paper verdict
    assert set(report.PAPER_FINDINGS) == set(rep["findings"])
    for d in rep["findings"].values():
        assert isinstance(d["paper_holds"], bool)
        assert "status" in d["measured"]
    # the three acceptance quantities are present and diffed
    cpm = rep["cost_per_million_sentences"]
    assert set(cpm) == {"AWS/C", "AWS/G"}
    for d in cpm.values():
        assert d["paper_usd_per_1m"] is not None
        assert (d["measured_usd_per_1m"] == float("inf")
                or d["measured_usd_per_1m"] > 0)
    ch = rep["cheapest_slo_compliant"]
    assert ch["target_ns"] == 2                   # largest cell in grid
    assert "measured" in ch and "paper_among_grid_profiles" in ch
    prem = rep["gpu_vs_cpu_premium"]
    assert prem["paper_table5_ratio_overall"] == pytest.approx(
        costmodel.gpu_cost_premium()["overall"])
    assert prem["grid_price_ratio"] == pytest.approx(
        profiles.profile("AWS", "G").hourly_cost_usd
        / profiles.profile("AWS", "C").hourly_cost_usd)
    # the formatter renders without crashing and names every finding
    text = report.format_drift(rep)
    for name in report.PAPER_FINDINGS:
        assert name in text
    assert not math.isnan(prem["paper_table5_ratio_overall"])
