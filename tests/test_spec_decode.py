"""Speculative decoding (draft-and-verify) in the continuous engine.

The contract under test: speculation is a latency optimization, never a
sampler change. A draft model proposes ``spec_k`` tokens per row per
round, the target verifies them in one chunked forward, and the engine
commits the agreed prefix plus one target-selected token, rolling both
KV pools back to each row's commit boundary — and the resulting token
streams must be identical to plain (non-speculative) decode, whatever
other serving feature is stacked on top. The matrix here pins that
identity across prefix cache, int8 weight/KV quant, adaptive and fixed
segment widths, chunked prefill and concurrent multi-lane traffic, each
cell with a measured window asserting zero jit compiles after
``warmup()``. A hypothesis property pins the per-row KV rollback
bookkeeping (the generalization of ``scatter_back`` that desynchronized
row positions force), and a meta-test promotes the offline hypothesis
shim's determinism into a tested contract.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import decode_segment, init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.kvcache import CachePool
from repro.serving.scheduler import pick_tier, width_tiers

CFG = get_config("qwen2-0.5b", smoke=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
DRAFT_CFG = dataclasses.replace(CFG, name="qwen2-0.5b-smoke-draft",
                                n_layers=1, d_model=112, n_heads=7,
                                n_kv_heads=1, d_ff=256)
DRAFT_PARAMS = init_params(DRAFT_CFG, jax.random.PRNGKey(1))
RNG = np.random.RandomState(23)


def _engine(spec=False, **kw):
    base = dict(mode="decoder", max_batch=2, max_new_tokens=4,
                pad_buckets=(16, 32), decode_segment=2)
    if spec:
        base.update(spec_decode=True, spec_k=2)
    base.update(kw)                       # kw wins, so cells can override
    return ServingEngine(CFG, PARAMS, EngineConfig(**base),
                         draft=(DRAFT_CFG, DRAFT_PARAMS) if spec else None)


def _prompt(n):
    return RNG.randint(0, CFG.vocab_size, (n,))


# 4 prompts over both buckets; [1] shares a 10-token prefix with [0] (the
# prefix-store hit when prefill_chunk=8: suffix fits one chunk), [3] spans
# multiple chunks; [2] finishes early on a 2-token budget (the mid-round
# commit clamp)
P0 = _prompt(14)
PROMPTS = [P0, np.concatenate([P0[:10], _prompt(4)]), _prompt(9),
           _prompt(27)]
SAMPLING = [SamplingParams(), SamplingParams(),
            SamplingParams(max_new_tokens=2), SamplingParams()]


def _run(eng, sequential):
    """Serve the shared traffic; sequential guarantees prefix-store hits
    (request 1 only hits after request 0's insert-on-complete)."""
    if sequential:
        return [np.asarray(eng.generate(p, s).result(timeout=300).tokens)
                for p, s in zip(PROMPTS, SAMPLING)]
    hs = [eng.generate(p, s) for p, s in zip(PROMPTS, SAMPLING)]
    return [np.asarray(h.result(timeout=300).tokens) for h in hs]


# --------------------------------------------------- cross-feature matrix
MATRIX = [
    ("plain", {}),
    ("chunked", dict(prefill_chunk=8)),
    ("prefix_cache", dict(prefill_chunk=8, prefix_cache=True)),
    ("quant", dict(kv_quant="int8", weight_quant="int8")),
    ("segment_fixed", dict(segment_width="fixed")),
    ("multi_lane", dict(multi_lane=True)),
]


@pytest.mark.parametrize("name,feat", MATRIX, ids=[m[0] for m in MATRIX])
def test_spec_decode_identity_matrix(name, feat):
    """Acceptance: greedy spec decode is token-identical to the same
    engine with speculation off, under every stacked serving feature —
    and the spec engine's measured window is compile-clean after
    warmup() (draft prefills, verify chunks and per-row rollbacks are
    all primed; nothing specializes mid-serve)."""
    sequential = name == "prefix_cache"
    base = _engine(**feat)
    try:
        want = _run(base, sequential)
    finally:
        base.close()
    eng = _engine(spec=True, **feat)
    try:
        eng.warmup()
        eng.window()                      # measured span starts here
        got = _run(eng, sequential)
        w = eng.window()
        assert w["jit_compiles"] == 0
        lanes = w["lanes"]
        assert sum(s["spec_rounds"] for s in lanes.values()) >= 1
        assert sum(s["spec_proposed"] for s in lanes.values()) > 0
        if sequential:                    # the store actually got hit
            assert sum(s["prefix_hits"] for s in lanes.values()) >= 1
    finally:
        eng.close()
    for i, (a, b) in enumerate(zip(want, got)):
        assert np.array_equal(a, b), (name, i)
        # budget accounting is exact: positions never regress and every
        # round's commit is clamped to the row's remaining budget
        assert len(b) == (SAMPLING[i].max_new_tokens or 4), (name, i)


def test_spec_decode_identity_sampled():
    """Seeded sampling composes too: the per-(seed, position) counter PRNG
    makes the verify chunk's row j sample exactly what a plain decode
    step at that position would, so acceptance is well-defined and the
    streams match bit-for-bit."""
    s = [SamplingParams(temperature=0.8, top_k=16, seed=9),
         SamplingParams()]
    outs = []
    for spec in (False, True):
        eng = _engine(spec=spec)
        try:
            hs = [eng.generate(p, sp)
                  for p, sp in zip([PROMPTS[0], PROMPTS[2]], s)]
            outs.append([np.asarray(h.result(timeout=300).tokens)
                         for h in hs])
        finally:
            eng.close()
    for a, b in zip(*outs):
        assert np.array_equal(a, b)


def test_spec_decode_metrics_and_validation():
    eng = _engine(spec=True)
    try:
        _run(eng, sequential=False)
        m = eng.metrics()["lanes"]
        prop = sum(s["spec_proposed"] for s in m.values())
        acc = sum(s["spec_accepted"] for s in m.values())
        assert prop > 0 and 0 <= acc <= prop
        for s in m.values():
            if s["spec_proposed"]:
                assert s["spec_accept_rate"] == pytest.approx(
                    s["spec_accepted"] / s["spec_proposed"])
    finally:
        eng.close()
    with pytest.raises(ValueError, match="draft"):
        _engine(spec_decode=True)         # spec without a draft model
    with pytest.raises(ValueError, match="spec_k"):
        _engine(spec=True, spec_k=0)
    with pytest.raises(ValueError, match="continuous"):
        _engine(spec=True, continuous=False)
    with pytest.raises(ValueError, match="vocab"):
        bad = dataclasses.replace(DRAFT_CFG, vocab_size=77)
        ServingEngine(CFG, PARAMS, EngineConfig(
            mode="decoder", max_batch=2, max_new_tokens=4,
            pad_buckets=(16,), spec_decode=True),
            draft=(bad, DRAFT_PARAMS))


# ------------------------------------------------ rollback bookkeeping
@settings(deadline=None, max_examples=6)
@given(mask=st.integers(1, 2 ** 4 - 1), seed=st.integers(0, 50),
       base_bound=st.integers(0, 5))
def test_scatter_rollback_per_row_truncation_property(mask, seed,
                                                      base_bound):
    """Property: compact-gather -> mutate -> per-row scatter_rollback
    touches exactly the compacted slots (everything else stays bitwise
    identical, extending the scatter_back round-trip property), and for
    each rolled row the cache obeys the spec commit contract: ring
    positions at or past the row's boundary are re-written to the empty
    sentinel before any later read (a verify chunk attends the whole
    ring, so a stale rolled-back position would leak rejected KV), ring
    positions below it survive verbatim, the length gauge never exceeds
    the boundary, and payload keys are copied through untouched."""
    slots = [i for i in range(4) if mask >> i & 1]
    occ = len(slots)
    width = pick_tier(occ, width_tiers(4))
    pool = CachePool(CFG, 4, 24, dtype=jnp.float32)
    leaves, treedef = jax.tree.flatten(pool.caches)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    pool.caches = jax.tree.unflatten(treedef, [
        (jax.random.normal(k, l.shape, l.dtype)
         if jnp.issubdtype(l.dtype, jnp.floating) else l)
        for k, l in zip(keys, leaves)])
    before = [np.asarray(x) for x in jax.tree.leaves(pool.caches)]
    lengths_before = list(pool.lengths)
    idx, view = pool.compact_view(slots, width)
    _, _, _, out = decode_segment(
        CFG, PARAMS, jnp.zeros((width, 1), jnp.int32),
        jnp.full((width, 1), 3, jnp.int32), view, n_steps=2,
        active=jnp.arange(width) < occ,
        budget=jnp.full((width,), 5, jnp.int32))
    # per-row boundaries (distinct on purpose: the whole point of the
    # rollback is that each row truncates at its own commit depth)
    bnds = np.asarray([(base_bound + j) % 6 for j in range(occ)], np.int32)
    pool.scatter_rollback(slots, out, bnds)
    after = [np.asarray(x) for x in jax.tree.leaves(pool.caches)]
    others = [i for i in range(4) if i not in slots]
    for b, a in zip(before, after):
        assert (b[:, others] == a[:, others]).all()
    for blk, d in out.items():
        for key, leaf in d.items():
            src = np.asarray(leaf)[:, :occ]       # padding rows dropped
            got = np.asarray(pool.caches[blk][key])[:, slots]
            if key == "pos":
                exp = np.where(src < bnds[None, :, None], src, -1)
                assert (got[got >= 0] < np.broadcast_to(
                    bnds[None, :, None], got.shape)[got >= 0]).all()
            elif key == "len":
                exp = np.minimum(src, bnds[None, :])
                assert (got <= bnds[None, :]).all()
            else:
                exp = src
            assert (got == exp).all(), (blk, key)
    assert pool.lengths == lengths_before     # gauges only move when asked
    assert pool.request_of == [None] * 4


# ------------------------------------------------- shim determinism meta
def test_hypothesis_shim_generates_identical_sequences():
    """The offline `_hypothesis_shim` replaces real hypothesis in
    environments that cannot install it, and the suite's reproducibility
    rests on its draws being identical across collections. Promote that
    from an implementation detail to a contract: two fresh decorated
    probes draw exactly max_examples examples each, and the sequences
    match element-for-element. Targets the shim module directly so the
    test also runs (and means the same thing) where real hypothesis is
    installed and the shim is inert."""
    import _hypothesis_shim as shim

    def collect():
        drawn = []

        @shim.settings(max_examples=7)
        @shim.given(a=shim.integers(0, 1000),
                    b=shim.floats(0.25, 4.0),
                    c=shim.sampled_from(["x", "y", "z"]),
                    d=shim.booleans())
        def probe(a, b, c, d):
            drawn.append((a, b, c, d))

        probe()
        return drawn

    first, second = collect(), collect()
    assert len(first) == 7
    assert first == second
