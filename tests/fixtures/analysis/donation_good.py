"""Known-good donation fixture — every idiom here must stay clean.

These mirror the real call sites in serving/kvcache.py and
serving/continuous.py: donate-and-rebind in one statement, donate into
a different binding then never touch the old one, kill-on-store before
the next read, and reads of *other* attributes of the donated object's
owner.
"""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=0)
def reset(caches, val):
    return caches.at[:].set(val)


step = jax.jit(lambda c, x: c + x, donate_argnums=0)

plain = jax.jit(lambda c, x: c + x)     # no donation: free to reuse args


def rebind_same_statement(pool, val):
    pool.caches = reset(pool.caches, val)     # the kvcache.py idiom
    return pool.caches.sum()


def store_kills_taint(caches):
    out = reset(caches, 0)
    caches = out                    # explicit rebind before any read
    return caches + 1


def donate_and_drop(pool):
    view = reset(pool.caches, 0)
    pool.caches = view              # scatter-back: prefix store kills all
    return pool.caches


def sibling_fields_stay_free(pool):
    out = reset(pool.caches, 0)
    n = pool.nslots                 # not under the donated path
    pool.caches = out
    return n


def loop_rebinds_every_iteration(pool):
    for i in range(3):
        pool.caches = reset(pool.caches, i)   # warmup-loop idiom
    return pool.caches


def non_donating_jit_is_free(caches):
    out = plain(caches, 1)
    return out + caches             # fine: nothing was donated
