"""Known-bad donation fixture — parsed by the lint tests, never imported.

Lines carrying ``EXPECT: donation`` must be flagged by the donation
pass (and nothing else in this file may be).
"""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=0)
def reset(caches, val):
    return caches.at[:].set(val)


step = jax.jit(lambda c, x: c + x, donate_argnums=0)


def read_after_donate(caches):
    out = reset(caches, 0)
    total = caches.sum()                        # EXPECT: donation
    return out, total


def rebind_then_reuse(c):
    c = step(c, 1)                  # clean: rebound in the same statement
    ok = c.sum()
    out = step(c, 2)
    return out, c.mean()                        # EXPECT: donation


def loop_back_edge(pool):
    for _ in range(3):
        view = reset(pool.caches, 1)            # EXPECT: donation
    return view


def branch_survives(caches, flag):
    out = reset(caches, 0)
    if flag:
        caches = out                # killed on this path only
    return caches + 1                           # EXPECT: donation


class Pool:
    def _seg(self):
        if "seg" not in self.compiled:
            self.compiled["seg"] = jax.jit(lambda c: c * 2,
                                           donate_argnums=0)
        return self.compiled["seg"]

    def factory_misuse(self):
        out = self._seg()(self.caches)
        stale = self.caches + 1                 # EXPECT: donation
        return out, stale
