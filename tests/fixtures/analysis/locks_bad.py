"""Known-bad lock-discipline fixture — parsed only, never imported.

Each ``EXPECT: locks`` line touches an annotated field outside its
declared guard.
"""
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()   # guarded-by: threadsafe
        self._overflow = []             # guarded-by: _lock
        self.stats = {}                 # guarded-by: worker
        self.limit = 8                  # guarded-by: init

    def submit(self, item):
        self._overflow.append(item)                 # EXPECT: locks
        with self._lock:
            self._overflow.append(item)   # clean: lock held

    def helper_without_marker(self):
        return len(self._overflow)                  # EXPECT: locks

    def bump_stats(self):       # carries no worker-ownership marker
        self.stats["n"] = 1                         # EXPECT: locks

    def reconfigure(self):
        self.limit = 16                             # EXPECT: locks

    def closure_escapes_lock(self):
        with self._lock:
            def later():
                self._overflow.clear()              # EXPECT: locks
            return later


class InternalQueue:
    def __init__(self):
        self._heap = []                 # guarded-by: external


class Meddler:
    def poke(self, q: InternalQueue):
        q._heap.append(1)                           # EXPECT: locks
