"""Known-good lock-discipline fixture — every guarded access pattern
the serving modules use; all must stay clean."""
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()   # guarded-by: threadsafe
        self._overflow = []             # guarded-by: _lock
        self.stats = {}                 # guarded-by: worker
        self.limit = 8                  # guarded-by: init
        self.cursor = 0                 # guarded-by: client
        self._q = object()              # guarded-by: threadsafe
        self._overflow.append(None)     # clean: declaring __init__

    def submit(self, item):
        with self._lock:
            self._overflow.append(item)

    def _drop(self, item):  # holds: _lock
        """Caller holds _lock."""
        self._overflow.remove(item)

    def _run(self):  # holds: worker
        self.stats["segments"] = self.stats.get("segments", 0) + 1
        self._drain()

    def _drain(self):  # holds: worker
        with self._lock:
            while self._overflow:       # both guards held
                self.stats["n"] = len(self._overflow)
                self._overflow.pop()

    def read_init_field(self):
        return self.limit               # init fields are free to read

    def client_side(self):
        self.cursor += 1                # client-owned: unenforced
        return self._q                  # threadsafe: free


class InternalQueue:
    def __init__(self):
        self._heap = []                 # guarded-by: external
        self._seq = 0                   # guarded-by: external

    def push(self, item, other):
        self._heap.append(item)         # declaring class: allowed
        other._seq = self._seq          # peer instance, same class: allowed
