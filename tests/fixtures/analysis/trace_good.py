"""Known-good trace-safety fixture — trace-time-static idioms that the
kernel wrappers rely on; all must stay clean."""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnums=(0,), static_argnames=("k",))
def static_branches(cfg, x, k=4):
    if cfg:                         # static_argnums param
        x = x + 1
    if k > 2:                       # static_argnames param
        x = x * 2
    return x


@jax.jit
def shape_reads_are_static(x):
    B = x.shape[0]
    if B > 1:                       # shape-derived: resolved at trace time
        x = x.reshape(B, -1)
    n = int(x.ndim)                 # int() of a static attribute
    if len(x) > 2:                  # len() is the static leading dim
        x = x[:2]
    return x, n


@jax.jit
def is_none_dispatch(x, mask=None):
    if mask is None:                # identity check: no concretization
        return x
    return jnp.where(mask, x, 0)


def host_side_is_free(x):
    t = time.time()                 # not jitted: host calls are fine
    arr = np.asarray(x)
    return arr.sum().item(), t


@jax.jit
def overwrite_clears_taint(x):
    n = x + 1
    n = 3                           # rebound to a static value
    if n > 2:                       # no longer traced
        x = x * n
    return x
