"""Known-good recompile fixture — the sanctioned jit idioms; all must
stay clean."""
import functools

import jax

EPS = 1e-6          # constant module global: free to close over


def forward(cfg, params, tokens):
    return tokens


#: module-level binding — one wrapper, one persistent compile cache
#: (the post-fix core/gector.py shape)
jit_forward = jax.jit(forward, static_argnums=0)


def predict(cfg, params, toks):
    return jit_forward(cfg, params, toks)


def hoisted_above_loop(params, batches):
    f = jax.jit(forward)            # built once, reused every iteration
    return [f(None, params, b) for b in batches]


def aot_lower(cfg, params, toks):
    # jit(...).lower(...) is the deliberate AOT idiom (launch/dryrun.py):
    # the wrapper is intentionally single-use, compilation is the point
    return jax.jit(forward, static_argnums=0).lower(cfg, params, toks)


@functools.partial(jax.jit, static_argnames=("scale",))
def uses_constant_global(x, scale=1.0):
    return x * scale + EPS          # EPS is never rebound: safe to bake


def hashable_static(params, toks):
    return jit_forward((1, 2), params, toks)    # tuple: hashable, cached


class Engine:
    def _segment_fn(self):
        # the engine's cached-factory idiom: built once per key, stored,
        # reused — the jit is not in a loop and not inline at a call site
        if "seg" not in self._compiled:
            self._compiled["seg"] = jax.jit(forward, static_argnums=0)
        return self._compiled["seg"]
