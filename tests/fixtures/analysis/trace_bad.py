"""Known-bad trace-safety fixture — parsed only, never imported.

Each ``EXPECT: trace`` line is a host sync or Python control flow on
a traced value inside a directly-jitted function.
"""
import functools
import time

import jax
import numpy as np


@jax.jit
def pulls_item(x):
    v = x.sum().item()                          # EXPECT: trace
    return v


@functools.partial(jax.jit, static_argnames=("flag",))
def branches_on_traced(x, flag):
    if flag:                        # clean: static parameter
        x = x + 1
    if x.sum() > 0:                             # EXPECT: trace
        x = x - 1
    return x


@jax.jit
def loops_on_traced(x):
    while x > 0:                                # EXPECT: trace
        x = x - 1
    return x


@jax.jit
def host_round_trip(x):
    y = np.asarray(x)                           # EXPECT: trace
    t = time.time()                             # EXPECT: trace
    return y, t


def converts_traced(x, n):
    scale = float(x)                            # EXPECT: trace
    return scale * n


jitted_by_reference = jax.jit(converts_traced, static_argnames=("n",))


@jax.jit
def taint_flows_through_assignment(x):
    y = x * 2
    z = y + 1
    if z:                                       # EXPECT: trace
        z = z + 1
    return z
