"""Known-bad recompile-hazard fixture — parsed only, never imported.

``predict`` reproduces the pre-fix core/gector.py:75 bug verbatim
shape-wise: jit built inline at the call site, fresh compile cache per
call. Each ``EXPECT: recompile`` line defeats the jit cache (or will
raise on first call).
"""
import jax

counter = 0


def forward(cfg, params, tokens):
    return tokens


def predict(cfg, params, toks):
    return jax.jit(forward, static_argnums=0)(cfg, params, toks)  # EXPECT: recompile


def jit_in_loop(params, batches):
    outs = []
    for b in batches:
        f = jax.jit(forward)                        # EXPECT: recompile
        outs.append(f(None, params, b))
    return outs


bad_index = jax.jit(forward, static_argnums=5)      # EXPECT: recompile

bad_name = jax.jit(forward, static_argnames=("nope",))  # EXPECT: recompile

g = jax.jit(forward, static_argnums=0)


def unhashable_static(params, toks):
    return g([1, 2], params, toks)                  # EXPECT: recompile


@jax.jit
def closes_over_mutable(x):
    return x + counter                              # EXPECT: recompile


def bump():
    global counter
    counter += 1
