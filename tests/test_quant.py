"""Quantized serving subsystem: weight int8 round-trip bounds (property),
policy selection, the dequant-fused matmul vs its reference (Pallas
interpret + XLA backends), qeinsum parity against dequantize-then-einsum,
int8 KV round-trip + pool scatter bitwise-stability of untouched slots,
and the engine knobs — bf16 default stays quant-free, kv_quant="int8"
serves token-correctly under lanes/tiers/chunked prefill/prefix cache,
and validation rejects unknown modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels import ops
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ref import int8_matmul_ref
from repro.models import decode_segment, init_params, make_caches
from repro.quant import (default_policy, dequantize_kv, dequantize_leaf,
                         dequantize_params, is_quantized, params_bytes,
                         qeinsum, quantize_kv, quantize_leaf,
                         quantize_params, quantized_leaf_count,
                         validate_kv_quant)
from repro.serving import EngineConfig, SamplingParams, ServingEngine
from repro.serving.kvcache import CachePool
from repro.serving.scheduler import pick_tier, width_tiers

R = jax.random.PRNGKey
CFG = get_config("qwen2-0.5b", smoke=True)
PARAMS = init_params(CFG, R(0))
RNG = np.random.RandomState(7)


def _engine(**kw):
    base = dict(mode="decoder", max_batch=4, max_new_tokens=6,
                pad_buckets=(16, 32), decode_segment=2)
    base.update(kw)
    return ServingEngine(CFG, PARAMS, EngineConfig(**base))


def _prompt(n):
    return RNG.randint(0, CFG.vocab_size, (n,))


# ------------------------------------------------- weight round-trip bound
@settings(deadline=None, max_examples=10)
@given(k=st.integers(1, 96), n=st.integers(1, 96),
       nc=st.integers(1, 2), stacked=st.booleans(),
       scale_exp=st.integers(-6, 4), seed=st.integers(0, 99))
def test_quantize_leaf_roundtrip_bound(k, n, nc, stacked, scale_exp, seed):
    """Property: symmetric per-channel int8 round-trip error is bounded by
    half a quantization step (scale / 2) everywhere — including extreme
    magnitudes (scale 2^4) and near-zero leaves (2^-6), for both 1- and
    2-axis contractions and period-stacked (n_batch=1) leaves."""
    shape = (k, n) if nc == 1 else (k, 3, n)
    if stacked:
        shape = (2,) + shape
    n_batch = 1 if stacked else 0
    w = jax.random.normal(R(seed), shape, jnp.float32) * 2.0 ** scale_exp
    leaf = quantize_leaf(w, nc, n_batch=n_batch)
    assert is_quantized(leaf) and leaf["qw"].dtype == jnp.int8
    assert leaf["qw"].shape == shape
    assert leaf["scale"].shape == shape[:n_batch] + shape[n_batch + nc:]
    back = dequantize_leaf(leaf, jnp.float32, n_batch=n_batch)
    # broadcast scale back over the contraction axes for the bound
    step = np.asarray(leaf["scale"])[
        (slice(None),) * n_batch + (np.newaxis,) * nc]
    err = np.abs(np.asarray(back) - np.asarray(w))
    assert (err <= step / 2 + 1e-12).all()


def test_quantize_leaf_zero_channel_exact():
    w = jnp.zeros((8, 4), jnp.float32).at[:, 1].set(3.0)
    leaf = quantize_leaf(w, 1)
    assert float(leaf["scale"][0]) == 0.0          # dead channel: scale 0
    back = dequantize_leaf(leaf, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


def test_quantize_params_policy_and_bytes():
    """The default policy quantizes attention + MLP projections only —
    embeddings/norms/lm_head stay float — and shrinks resident bytes."""
    qp = quantize_params(PARAMS)
    assert quantized_leaf_count(qp) > 0
    assert quantized_leaf_count(PARAMS) == 0
    assert params_bytes(qp) < params_bytes(PARAMS)
    assert not is_quantized(qp["embed"])           # policy exclusions
    flat = {jax.tree_util.keystr(p): l for p, l in
            jax.tree_util.tree_flatten_with_path(
                qp, is_leaf=is_quantized)[0]}
    for path, leaf in flat.items():
        if "norm" in path or "embed" in path or "lm_head" in path:
            assert not is_quantized(leaf), path
    # round trip through the policy stays within the per-leaf bound
    back = dequantize_params(qp)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(PARAMS)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        bound = 0.5 * float(np.abs(np.asarray(a, np.float32)).max()) / 127
        assert np.abs(np.asarray(a, np.float32)
                      - np.asarray(b, np.float32)).max() \
            <= max(1e-6, bound * 1.001), pa


# ------------------------------------------------------ dequant-fused matmul
@settings(deadline=None, max_examples=8)
@given(m=st.integers(1, 150), k=st.integers(1, 150), n=st.integers(1, 150),
       impl=st.sampled_from(["xla", "pallas"]))
def test_matmul_q8_matches_ref(m, k, n, impl):
    x = jax.random.normal(R(m), (m, k), jnp.float32)
    qw = jax.random.randint(R(n), (k, n), -127, 128, jnp.int8)
    scale = jax.random.uniform(R(m + n), (n,), jnp.float32, 1e-3, 2e-2)
    prev = ops.set_quant_matmul_impl(impl)
    try:
        out = ops.matmul_q8(x, qw, scale, bm=64, bn=64, bk=64)
    finally:
        ops.set_quant_matmul_impl(prev)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(int8_matmul_ref(x, qw, scale)),
        rtol=1e-4, atol=1e-4)


def test_int8_matmul_kernel_direct():
    # block-multiple shapes hit the Pallas kernel without padding
    x = jax.random.normal(R(0), (128, 256), jnp.float32)
    qw = jax.random.randint(R(1), (256, 128), -127, 128, jnp.int8)
    scale = jax.random.uniform(R(2), (128,), jnp.float32, 1e-3, 2e-2)
    out = int8_matmul(x, qw, scale, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(int8_matmul_ref(x, qw, scale)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("eq,xshape,wshape,nc", [
    ("bsd,df->bsf", (2, 5, 16), (16, 24), 1),       # mlp up
    ("bsd,dcf->bscf", (2, 5, 16), (16, 3, 24), 1),  # fused qkv
    ("bshd,hdf->bsf", (2, 5, 4, 8), (4, 8, 16), 2),  # wo merge
])
def test_qeinsum_matches_dequant_einsum(eq, xshape, wshape, nc):
    """qeinsum on a quantized leaf equals dequantize-then-einsum (no
    materialized float weights on the fused path), and passes floats
    through to a bit-identical jnp.einsum."""
    x = jax.random.normal(R(0), xshape, jnp.bfloat16)
    w = jax.random.normal(R(1), wshape, jnp.float32) * 0.05
    np.testing.assert_array_equal(
        np.asarray(qeinsum(eq, x, w), np.float32),
        np.asarray(jnp.einsum(eq, x, w), np.float32))
    leaf = quantize_leaf(w, nc)
    got = np.asarray(qeinsum(eq, x, leaf), np.float32)
    want = np.asarray(jnp.einsum(
        eq, x, dequantize_leaf(leaf, x.dtype)), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------- KV quant
@settings(deadline=None, max_examples=10)
@given(h=st.integers(1, 4), d=st.integers(1, 64),
       scale_exp=st.integers(-6, 4), seed=st.integers(0, 99))
def test_kv_roundtrip_bound(h, d, scale_exp, seed):
    x = jax.random.normal(R(seed), (3, h, d), jnp.float32) * 2.0 ** scale_exp
    q, scale = quantize_kv(x)
    assert q.dtype == jnp.int8 and scale.shape == (3, h)
    back = dequantize_kv(q, scale, jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= np.asarray(scale)[..., None] / 2 + 1e-12).all()


def test_kv_zero_vector_exact():
    q, scale = quantize_kv(jnp.zeros((2, 2, 8), jnp.bfloat16))
    assert (np.asarray(q) == 0).all() and (np.asarray(scale) == 0).all()
    assert (np.asarray(dequantize_kv(q, scale, jnp.bfloat16)) == 0).all()


def test_make_caches_kv_quant_layout():
    from repro.models import make_caches as mk
    caches = mk(CFG, 2, 24, dtype=jnp.float32, kv_quant="int8")
    for c in caches.values():
        assert c["k"].dtype == jnp.int8 and c["v"].dtype == jnp.int8
        # leading axes may stack period layers; slot planes are the tail
        assert c["k_scale"].shape[-3:] == (2, 24, CFG.n_kv_heads)
        assert c["k_scale"].dtype == jnp.float32
    default = mk(CFG, 2, 24, dtype=jnp.float32)
    for c in default.values():
        assert "k_scale" not in c and c["k"].dtype == jnp.float32


@settings(deadline=None, max_examples=6)
@given(mask=st.integers(1, 2 ** 4 - 1), seed=st.integers(0, 50))
def test_int8_pool_scatter_leaves_other_slots_untouched(mask, seed):
    """Property (int8 pool): compact-gather -> decode segment -> scatter
    touches exactly the compacted slots; every other slot's quantized KV
    *and its scale plane* stay bitwise identical."""
    slots = [i for i in range(4) if mask >> i & 1]
    width = pick_tier(len(slots), width_tiers(4))
    pool = CachePool(CFG, 4, 24, dtype=jnp.float32, kv_quant="int8")
    leaves, treedef = jax.tree.flatten(pool.caches)
    keys = jax.random.split(R(seed), len(leaves))
    pool.caches = jax.tree.unflatten(treedef, [
        (jax.random.normal(k, l.shape, l.dtype)
         if jnp.issubdtype(l.dtype, jnp.floating) else
         jax.random.randint(k, l.shape, -127, 128, l.dtype)
         if l.dtype == jnp.int8 else l)
        for k, l in zip(keys, leaves)])
    before = [np.asarray(x) for x in jax.tree.leaves(pool.caches)]
    occ = len(slots)
    idx, view = pool.compact_view(slots, width)
    _, _, _, out = decode_segment(
        CFG, PARAMS, jnp.zeros((width, 1), jnp.int32),
        jnp.full((width, 1), 3, jnp.int32), view, n_steps=2,
        active=jnp.arange(width) < occ,
        budget=jnp.full((width,), 5, jnp.int32))
    pool.scatter_back(slots, out)
    after = [np.asarray(x) for x in jax.tree.leaves(pool.caches)]
    others = [i for i in range(4) if i not in slots]
    changed = False
    for b, a in zip(before, after):
        assert (b[:, others] == a[:, others]).all()
        if not np.array_equal(b[:, slots], a[:, slots]):
            changed = True
    assert changed


# ------------------------------------------------------------ engine knobs
def test_default_path_stays_quant_free():
    """bf16/f32 default: no quantized leaves, no scale planes, and the
    engine's params object is the caller's (bit-identity with pre-quant
    engines follows — nothing on the path changed)."""
    eng = _engine()
    try:
        assert eng.params is PARAMS
        assert quantized_leaf_count(eng.params) == 0
        pool = eng._get_pool(16)
        assert all("k_scale" not in c for c in jax.tree.leaves(
            pool.caches, is_leaf=lambda x: isinstance(x, dict)))
    finally:
        eng.close()


def test_quant_validation():
    with pytest.raises(ValueError, match="weight_quant"):
        _engine(weight_quant="int4")
    with pytest.raises(ValueError, match="kv_quant"):
        _engine(kv_quant="fp8")
    with pytest.raises(ValueError, match="decoder"):
        ServingEngine(get_config("gector-base", smoke=True),
                      init_params(get_config("gector-base", smoke=True),
                                  R(0)),
                      EngineConfig(mode="encoder", kv_quant="int8"))
    validate_kv_quant(None)
    validate_kv_quant("int8")


@pytest.mark.parametrize("kw", [
    dict(),                                          # lanes, adaptive tiers
    dict(segment_width="fixed", prefill_chunk=8),    # fixed + chunked
    dict(prefill_chunk=8, prefix_cache=True),        # prefix sharing
])
def test_kv_quant_int8_serves_under_scheduler_features(kw):
    """kv_quant='int8' must keep every scheduler feature working: lanes
    across buckets, adaptive + fixed width tiers, chunked prefill, and the
    prefix cache — completing requests with full token budgets and
    surfacing the per-lane kv_bytes gauge."""
    # chunk-aligned shared prefix (2 x prefill_chunk=8) so the cold insert
    # lands exactly on the shared region and later lookups hit it
    shared = _prompt(16)
    prompts = [np.concatenate([shared, _prompt(4)]) for _ in range(3)]
    prompts.append(_prompt(12))                      # second bucket lane
    eng = _engine(kv_quant="int8", **kw)
    try:
        if kw.get("prefix_cache"):                   # cold insert first
            assert len(eng.generate(prompts[0])
                       .result(timeout=300).tokens) == 6
        hs = [eng.generate(p) for p in prompts]
        outs = [h.result(timeout=300).tokens for h in hs]
        assert all(len(o) == 6 for o in outs)
        m = eng.metrics()
        assert any(s.get("kv_bytes", 0) > 0 for s in m["lanes"].values())
        if kw.get("prefix_cache"):
            assert sum(s.get("prefix_hits", 0)
                       for s in m["lanes"].values()) >= 1
    finally:
        eng.close()


def test_kv_quant_adaptive_matches_fixed():
    """Width-tier compaction must not change tokens under int8 KV — the
    gather/scatter carries the scale planes with the slots."""
    prompts = [_prompt(n) for n in (27, 9, 14, 30)]
    sampling = [SamplingParams(max_new_tokens=t) for t in (6, 2, 5, 3)]
    outs = {}
    for mode in ("fixed", "adaptive"):
        eng = _engine(kv_quant="int8", segment_width=mode)
        try:
            hs = [eng.generate(p, s) for p, s in zip(prompts, sampling)]
            outs[mode] = [h.result(timeout=300).tokens for h in hs]
        finally:
            eng.close()
    for a, b in zip(outs["fixed"], outs["adaptive"]):
        assert (a == b).all()


def test_weight_quant_engine_serves_and_shrinks_weights():
    eng = _engine(weight_quant="int8", kv_quant="int8")
    try:
        assert quantized_leaf_count(eng.params) > 0
        assert eng.metrics()["weight_bytes"] < params_bytes(PARAMS)
        h = eng.generate(_prompt(10))
        assert len(h.result(timeout=300).tokens) == 6
        assert "weight_bytes" in eng.window()
    finally:
        eng.close()


def test_default_policy_class_listing():
    pol = default_policy()
    assert pol.n_contract("mlp", "w_in") == 1
    assert pol.n_contract("attn", "wo") == 2
    assert pol.n_contract("moe", "w_in") is None     # MoE excluded
    assert pol.n_contract("attn", "norm") is None
