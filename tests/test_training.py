"""Training substrate: optimizer math, schedules, ZeRO-1 spec derivation,
loss behaviour (chunked CE == full CE), checkpoint roundtrip, data pipeline
determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import forward, init_params
from repro.training import OptConfig, adamw_init, train_step
from repro.training.checkpoint import restore, save
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import (adamw_update, global_norm, lr_schedule,
                                      zero1_spec)
from repro.training.train_loop import chunked_ce, loss_fn


def test_adamw_reduces_simple_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                   weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, _ = adamw_update(oc, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_bounds_update():
    oc = OptConfig(lr=1.0, warmup_steps=0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, gn = adamw_update(oc, params, huge, state)
    assert float(gn) == pytest.approx(2e6, rel=1e-3)


def test_lr_schedule_shape():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(oc, s)) for s in range(101)]
    assert lrs[0] < lrs[10]                      # warmup
    assert lrs[10] == pytest.approx(1e-3, rel=1e-2)
    assert lrs[100] < lrs[50] < lrs[10]          # cosine decay


def test_zero1_spec_picks_replicated_axis():
    class FakeRules:
        zero1 = True
        def axis_size(self, name):
            return 4
    spec = zero1_spec(P(None, "model"), (8, 64), FakeRules())
    assert spec == P("data", "model")
    # refuses to shard non-divisible axes
    spec2 = zero1_spec(P(None, None), (3, 5), FakeRules())
    assert spec2 == P(None, None)


def test_chunked_ce_equals_full_ce():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    hidden, _, _ = forward(cfg, params, tokens=toks[:, :-1],
                           return_hidden=True)
    labels = toks[:, 1:]
    valid = jnp.ones_like(labels, bool)
    full = chunked_ce(cfg, params, hidden, labels, valid, seq_chunk=4096)
    chunked = chunked_ce(cfg, params, hidden, labels, valid, seq_chunk=16)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_loss_decreases_on_learnable_data():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    opt = adamw_init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  batch_size=4, seed=0))
    step = jax.jit(lambda p, o, b: train_step(cfg, oc, p, o, b))
    losses = []
    for b in data.batches(10):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-125m", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "x.ckpt")
    save(path, params)
    back = restore(path)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))


def test_data_pipeline_deterministic():
    dc = DataConfig(vocab_size=100, seq_len=16, batch_size=2, seed=7)
    a = [b["tokens"] for b in SyntheticLM(dc).batches(3)]
    b = [b["tokens"] for b in SyntheticLM(dc).batches(3)]
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=2 must give (numerically close) identical updates to the
    full-batch step for a loss that averages over tokens uniformly."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    oc = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                   weight_decay=0.0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    p1, _, m1 = train_step(cfg, oc, params, adamw_init(params), batch)
    p2, _, m2 = train_step(cfg, oc, params, adamw_init(params), batch,
                           accum_steps=2)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)
