"""Serving layer: engine batching, admission control (the paper's §4
proposal), cache pool slot management, GECToR end-to-end service."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.gector import init_gector
from repro.core.tags import TagVocab
from repro.models import init_params
from repro.serving import EngineConfig, ServingEngine
from repro.serving.kvcache import CachePool
from repro.serving.scheduler import AdmissionQueue


def _mk_engine(**kw):
    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServingEngine(cfg, params,
                              EngineConfig(mode="encoder", max_batch=8, **kw))


def test_engine_batches_concurrent_requests():
    cfg, eng = _mk_engine()
    try:
        futs = [eng.submit(np.random.randint(0, cfg.vocab_size, (12,)))
                for _ in range(16)]
        outs = [f.result(timeout=120) for f in futs]
        assert all(o.shape[-1] == cfg.d_model for o in outs)
        m = eng.metrics()
        assert m["requests"] == 16
        assert m["batch_size_mean"] > 1.0          # batching happened
    finally:
        eng.close()


def test_engine_decoder_mode_generates():
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="decoder", max_batch=4,
                                     max_new_tokens=3))
    try:
        futs = [eng.submit(np.random.randint(0, cfg.vocab_size, (8,)))
                for _ in range(4)]
        outs = [f.result(timeout=180) for f in futs]
        assert all(o.shape == (3,) for o in outs)
        assert all((o >= 0).all() and (o < cfg.padded_vocab).all()
                   for o in outs)
    finally:
        eng.close()


def test_admission_queue_bounds_inflight():
    q = AdmissionQueue(max_inflight=2)
    order = []
    import threading
    active = [0]
    peak = [0]
    lock = threading.Lock()

    def worker(i):
        with q:
            with lock:
                active[0] += 1
                peak[0] = max(peak[0], active[0])
            time.sleep(0.02)
            with lock:
                active[0] -= 1
            order.append(i)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert peak[0] <= 2
    assert q.stats.admitted == 8
    assert q.stats.queued_peak >= 2


def test_cache_pool_slot_lifecycle():
    cfg = get_config("qwen2-0.5b", smoke=True)
    pool = CachePool(cfg, n_slots=4, max_len=16, dtype=jnp.float32)
    s0 = pool.assign("req0")
    s1 = pool.assign("req1")
    assert s0 != s1 and pool.free_slots == 2
    # dirty a slot, release, re-assign -> reset to empty template
    pool.caches = jax.tree.map(lambda x: x + 1, pool.caches)
    pool.release(s0)
    s2 = pool.assign("req2")
    assert s2 == s0
    k = pool.caches["blk0"]["pos"][:, s2]
    assert (np.asarray(k) == -1).all()            # pos sentinel restored


def test_gector_served_end_to_end():
    cfg = get_config("gector-base", smoke=True)
    vocab = TagVocab(64)
    params = init_gector(cfg, jax.random.PRNGKey(0), vocab)

    def head(p, hid, mask):
        return jnp.argmax(hid.astype(jnp.float32) @ p["label_head"]["w"], -1)

    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder", max_batch=4),
                        head_fn=head)
    try:
        fut = eng.submit(np.random.randint(0, cfg.vocab_size, (10,)))
        tags = fut.result(timeout=120)
        assert tags.shape[0] >= 10 and (tags < vocab.n_tags).all()
    finally:
        eng.close()
