"""GECToR model behaviour: heads, loss, iterative correction mechanics, and
a short-budget learning signal (full training lives in examples/)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.corpus import CorpusConfig, GECCorpus
from repro.core.gector import (gector_forward, gector_loss, init_gector,
                               iterative_correct, predict_tags)
from repro.core.tags import KEEP, TagVocab, apply_edits
from repro.training.optimizer import OptConfig, adamw_init, adamw_update

CFG = get_config("gector-base", smoke=True)
VOCAB = TagVocab(64)


def test_heads_shapes():
    params = init_gector(CFG, jax.random.PRNGKey(0), VOCAB)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                              CFG.vocab_size)
    tag_logits, det_logits = gector_forward(CFG, params, toks)
    assert tag_logits.shape == (2, 20, VOCAB.n_tags)
    assert det_logits.shape == (2, 20, 2)


def test_loss_masks_and_weights():
    params = init_gector(CFG, jax.random.PRNGKey(0), VOCAB)
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     CFG.vocab_size),
        "tags": jnp.zeros((B, S), jnp.int32).at[:, 3].set(5),
        "mask": jnp.ones((B, S), bool).at[:, 10:].set(False),
    }
    loss, metrics = gector_loss(CFG, params, batch)
    assert jnp.isfinite(loss) and 0 <= float(metrics["tag_acc"]) <= 1


def test_iterative_correct_applies_edits_and_stops():
    params = init_gector(CFG, jax.random.PRNGKey(0), VOCAB)
    sents = [np.random.randint(0, CFG.vocab_size, (np.random.randint(5, 20),))
             for _ in range(6)]
    fixed = iterative_correct(CFG, params, VOCAB, sents, max_iters=2)
    assert len(fixed) == len(sents)
    assert all(len(f) > 0 for f in fixed)


def test_detect_gating_reduces_edits():
    params = init_gector(CFG, jax.random.PRNGKey(0), VOCAB)
    toks = np.random.randint(0, CFG.vocab_size, (4, 24))
    mask = np.ones_like(toks, bool)
    free = predict_tags(CFG, params, toks, mask, min_error_prob=0.0)
    gated = predict_tags(CFG, params, toks, mask, min_error_prob=0.99)
    assert np.sum(gated != KEEP) <= np.sum(free != KEEP)


def test_gector_learns_briefly():
    """30 steps on a high-error corpus must beat the initial loss clearly
    (full convergence is exercised by examples/train_gector.py)."""
    corpus = GECCorpus(CorpusConfig(vocab_size=CFG.vocab_size,
                                    edit_words=64, error_rate=0.4, seed=0))
    params = init_gector(CFG, jax.random.PRNGKey(0), corpus.vocab)
    oc = OptConfig(lr=2e-3, warmup_steps=3, total_steps=40,
                   weight_decay=0.01)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        (l, m), g = jax.value_and_grad(
            lambda pp: gector_loss(CFG, pp, b), has_aux=True)(p)
        p, o, _ = adamw_update(oc, p, g, o)
        return p, o, l

    losses = []
    for b in corpus.batches(8, 32, 30):
        params, opt, l = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(l))
    assert np.mean(losses[-5:]) < 0.75 * np.mean(losses[:3])


def test_apply_edits_semantics():
    v = TagVocab(10)
    toks = [5, 6, 7]
    # REPLACE first with word 2, DELETE second, APPEND word 9 after third
    tags = [v.replace(2), 1, v.append(9)]
    assert apply_edits(v, toks, tags) == [2, 7, 9]
