"""Model attention paths: chunked vs naive agreement, decode-vs-prefill
consistency, ring-buffer window caches, mLSTM parallel/recurrent exactness,
RG-LRU scan vs step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import forward, init_params, make_caches
from repro.models.attention import (chunked_attention, make_cache,
                                    naive_attention)

R = jax.random.PRNGKey


@settings(deadline=None, max_examples=8)
@given(s=st.integers(20, 300),
       window=st.sampled_from([None, 16, 64]),
       softcap=st.sampled_from([None, 30.0]))
def test_chunked_equals_naive(s, window, softcap):
    B, Hq, Hkv, D = 2, 4, 2, 16
    q = jax.random.normal(R(s), (B, s, Hq, D))
    k = jax.random.normal(R(s + 1), (B, s, Hkv, D))
    v = jax.random.normal(R(s + 2), (B, s, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(s), (B, s))
    a = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                          softcap=softcap, q_chunk=64, kv_chunk=64)
    b = naive_attention(q, k, v, pos, pos, causal=True, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                               rtol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma2-27b",
                                  "recurrentgemma-9b", "xlstm-125m"])
def test_decode_matches_prefill(arch):
    """Decoding token-by-token from a cache must reproduce the full-sequence
    forward logits (the serving-correctness invariant)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, R(0))
    B, S = 1, 24
    toks = jax.random.randint(R(1), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = forward(cfg, params, tokens=toks)

    caches = make_caches(cfg, B, 32, dtype=jnp.float32)
    step_logits = []
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches, _ = forward(cfg, params, tokens=toks[:, t:t + 1],
                                positions=pos, caches=caches, mode="decode")
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_window_ring_cache():
    """A window-sized ring cache gives the same decode attention as an
    unbounded cache restricted by the window mask."""
    cfg = get_config("gemma2-27b", smoke=True)
    from repro.models.attention import attn_decode, attn_init
    p = attn_init(cfg, R(0))
    B, W = 1, cfg.attn.window  # smoke window = 64
    big = make_cache(cfg, B, 256, dtype=jnp.float32)
    ring = make_cache(cfg, B, 256, window=W, dtype=jnp.float32)
    assert ring["k"].shape[1] == W
    outs_big, outs_ring = [], []
    for t in range(100):
        x = jax.random.normal(R(t), (B, 1, cfg.d_model), jnp.float32)
        pos = jnp.full((B, 1), t, jnp.int32)
        ob, big = attn_decode(cfg, p, x, pos, big, window=W)
        orr, ring = attn_decode(cfg, p, x, pos, ring, window=W)
        outs_big.append(ob)
        outs_ring.append(orr)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs_big, 1)),
                               np.asarray(jnp.concatenate(outs_ring, 1)),
                               atol=1e-4, rtol=1e-4)


def test_mlstm_parallel_equals_recurrent():
    from repro.models.xlstm import mlstm_apply, mlstm_init, mlstm_state
    cfg = get_config("xlstm-125m", smoke=True)
    p = mlstm_init(cfg, R(0))
    x = jax.random.normal(R(1), (2, 100, cfg.d_model), jnp.float32) * 0.5
    out_par, st_par = mlstm_apply(cfg, p, x)
    out_rec, st_rec = mlstm_apply(cfg, p, x, state=mlstm_state(cfg, 2))
    np.testing.assert_allclose(np.asarray(out_par), np.asarray(out_rec),
                               atol=2e-3, rtol=2e-2)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_par[k]),
                                   np.asarray(st_rec[k]), atol=1e-3,
                                   rtol=1e-2)


def test_rglru_scan_equals_step():
    from repro.models.rglru import (rglru_apply, rglru_init, rglru_state,
                                    rglru_step)
    cfg = get_config("recurrentgemma-9b", smoke=True)
    p = rglru_init(cfg, R(0))
    B, S = 2, 40
    x = jax.random.normal(R(1), (B, S, cfg.d_model), jnp.float32)
    out_full, st_full = rglru_apply(cfg, p, x, state=rglru_state(cfg, B))
    st = rglru_state(cfg, B)
    outs = []
    for t in range(S):
        o, st = rglru_step(cfg, p, x[:, t:t + 1], st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(out_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_full["h"]),
                               atol=1e-4, rtol=1e-3)


def test_fused_qkv_path_decode_matches_prefill():
    """kv=16 triggers the fused grouped-QKV layout (§Perf iteration B2);
    decode-from-cache must still reproduce full-forward logits."""
    import dataclasses
    from repro.models.config import AttnConfig, ModelConfig
    cfg = ModelConfig(name="fused-test", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=32, n_kv_heads=16, d_ff=256,
                      vocab_size=512, attn=AttnConfig(qkv_bias=True))
    assert cfg.fused_qkv
    params = init_params(cfg, R(7))
    assert "wqkv" in params["blocks"]["blk0"]["attn"]
    toks = jax.random.randint(R(8), (2, 16), 0, 512)
    full, _, _ = forward(cfg, params, tokens=toks)
    caches = make_caches(cfg, 2, 24, dtype=jnp.float32)
    outs = []
    for t in range(16):
        lg, caches, _ = forward(cfg, params, tokens=toks[:, t:t + 1],
                                positions=jnp.full((2, 1), t, jnp.int32),
                                caches=caches, mode="decode")
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1), np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_whisper_cross_kv_cache_decode():
    """Enc-dec serving: prefill fills the cross-KV cache; decode then runs
    WITHOUT the encoder and must match the full forward."""
    cfg = get_config("whisper-large-v3", smoke=True)
    params = init_params(cfg, R(0))
    B, S = 2, 10
    toks = jax.random.randint(R(1), (B, S), 0, cfg.vocab_size)
    enc = jax.random.normal(R(2), (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
    full, _, _ = forward(cfg, params, tokens=toks, enc_tokens_embeds=enc)
    caches = make_caches(cfg, B, 16, dtype=jnp.float32)
    assert "ck" in caches["blk0"]
    lg, caches, _ = forward(cfg, params, tokens=toks[:, :1], caches=caches,
                            mode="full", enc_tokens_embeds=enc)
    outs = [lg[:, -1]]
    for t in range(1, S):
        lg, caches, _ = forward(cfg, params, tokens=toks[:, t:t + 1],
                                positions=jnp.full((B, 1), t, jnp.int32),
                                caches=caches, mode="decode")
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1), np.float32),
                               np.asarray(full, np.float32),
                               atol=3e-2, rtol=3e-2)
