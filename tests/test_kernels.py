"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype
sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.cache_matmul import vmem_bytes
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               matmul_ref)

R = jax.random.PRNGKey


# ------------------------------------------------------------------ matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 64, 64), (200, 300, 170),
                                   (128, 256, 512), (33, 65, 17)])
def test_cache_matmul_shapes(shape, dtype):
    M, K, N = shape
    x = jax.random.normal(R(0), (M, K), dtype)
    w = jax.random.normal(R(1), (K, N), dtype)
    out = ops.matmul(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(matmul_ref(x, w)),
                               rtol=tol, atol=tol * 10)


@settings(deadline=None, max_examples=10)
@given(m=st.integers(1, 150), k=st.integers(1, 150), n=st.integers(1, 150))
def test_cache_matmul_property(m, k, n):
    x = jax.random.normal(R(m), (m, k), jnp.float32)
    w = jax.random.normal(R(n), (k, n), jnp.float32)
    out = ops.matmul(x, w, bm=64, bn=64, bk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


def test_vmem_budget():
    # default tiling stays within a 16 MiB VMEM budget (the paper's cache-
    # residency design rule, DESIGN.md §2)
    assert vmem_bytes(128, 128, 128) < 16 * 2**20
    assert vmem_bytes(512, 512, 512, jnp.bfloat16) < 16 * 2**20


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0), (32, 50.0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_variants(window, softcap, dtype):
    B, S, Hq, Hkv, D = 2, 192, 4, 2, 64
    q = jax.random.normal(R(0), (B, S, Hq, D), dtype)
    k = jax.random.normal(R(1), (B, S, Hkv, D), dtype)
    v = jax.random.normal(R(2), (B, S, Hkv, D), dtype)
    out = ops.mha_prefill(q, k, v, causal=True, window=window,
                          softcap=softcap, bq=64, bk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    ref = flash_attention_ref(qf, kf, vf, causal=True, window=window,
                              softcap=softcap)
    ref = ref.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), rtol=tol, atol=tol)


@settings(deadline=None, max_examples=8)
@given(s=st.integers(10, 200), hkv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]))
def test_flash_attention_property(s, hkv, g):
    B, D = 1, 32
    q = jax.random.normal(R(s), (B, s, hkv * g, D), jnp.float32)
    k = jax.random.normal(R(s + 1), (B, s, hkv, D), jnp.float32)
    v = jax.random.normal(R(s + 2), (B, s, hkv, D), jnp.float32)
    out = ops.mha_prefill(q, k, v, bq=64, bk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * hkv * g, s, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * hkv, s, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * hkv, s, D)
    ref = flash_attention_ref(qf, kf, vf).reshape(
        B, hkv * g, s, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------- decode attention
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("valid_len", [1, 17, 120])
def test_decode_attention(window, valid_len):
    B, Hq, Hkv, D, L = 2, 4, 2, 64, 150
    G = Hq // Hkv
    q = jax.random.normal(R(0), (B, 1, Hq, D), jnp.float32)
    k = jax.random.normal(R(1), (B, L, Hkv, D), jnp.float32)
    v = jax.random.normal(R(2), (B, L, Hkv, D), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(L), (B, L)).astype(jnp.int32)
    kv_pos = jnp.where(kv_pos < valid_len, kv_pos, -1)
    q_pos = jnp.full((B,), valid_len - 1, jnp.int32)
    out = ops.gqa_decode(q, k, v, q_pos, kv_pos, window=window, bk=64)
    qf = q[:, 0].reshape(B * Hkv, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, L, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, L, D)
    ref = decode_attention_ref(qf, kf, vf, jnp.repeat(q_pos, Hkv),
                               jnp.repeat(kv_pos, Hkv, axis=0),
                               window=window)
    np.testing.assert_allclose(np.asarray(out.reshape(B * Hkv, G, D)),
                               np.asarray(ref), rtol=3e-3, atol=3e-3)


def test_decode_matches_engine_attention():
    """Kernel agrees with the model's own decode attention path."""
    from repro.models.attention import naive_attention
    B, Hq, Hkv, D, L = 1, 4, 2, 32, 64
    q = jax.random.normal(R(3), (B, 1, Hq, D), jnp.float32)
    k = jax.random.normal(R(4), (B, L, Hkv, D), jnp.float32)
    v = jax.random.normal(R(5), (B, L, Hkv, D), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(L), (B, L)).astype(jnp.int32)
    q_pos = jnp.full((B,), L - 1, jnp.int32)
    out_kernel = ops.gqa_decode(q, k, v, q_pos, kv_pos)
    out_model = naive_attention(q, k, v, q_pos[:, None], kv_pos)
    np.testing.assert_allclose(
        np.asarray(out_kernel[:, 0]),
        np.asarray(out_model[:, 0].reshape(B, Hq, D)), rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------- rglru scan
@pytest.mark.parametrize("shape", [(1, 64, 128), (2, 300, 128),
                                   (3, 100, 256)])
def test_rglru_scan_kernel(shape):
    from repro.kernels.ref import rglru_scan_ref
    B, S, W = shape
    a = (jax.nn.sigmoid(jax.random.normal(R(0), (B, S, W))) * 0.2 + 0.79)
    b = jax.random.normal(R(1), (B, S, W)) * 0.1
    out = ops.lru_scan(a, b, bs=64)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)


@settings(deadline=None, max_examples=6)
@given(s=st.integers(5, 200), seed=st.integers(0, 50))
def test_rglru_scan_property(s, seed):
    from repro.kernels.ref import rglru_scan_ref
    B, W = 1, 128
    a = jax.nn.sigmoid(jax.random.normal(R(seed), (B, s, W)))
    b = jax.random.normal(R(seed + 1), (B, s, W)) * 0.1
    out = ops.lru_scan(a, b, bs=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(rglru_scan_ref(a, b)),
                               atol=1e-5, rtol=1e-4)


def test_rglru_kernel_matches_model_block():
    """The kernel agrees with the model's RG-LRU recurrence (rglru_apply's
    inner scan) for a carried-state-free sequence."""
    from repro.kernels.ref import rglru_scan_ref
    B, S, W = 2, 50, 128
    a = jax.nn.sigmoid(jax.random.normal(R(3), (B, S, W)))
    b = jax.random.normal(R(4), (B, S, W)) * 0.1
    # sequential reference
    h = jnp.zeros((B, W))
    hs = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    seq = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(ops.lru_scan(a, b, bs=64)),
                               np.asarray(seq), atol=1e-5, rtol=1e-4)
