"""Render the paper's Tables 2-4 cell-by-cell: measured (paper) vs the
calibrated model's prediction — the per-table reproduction artifact.

  PYTHONPATH=src python -m benchmarks.tables [--provider AWS]
"""
from __future__ import annotations

import argparse

from repro.core import perfsim
from repro.core.environments import MACHINES, MEASURED, NS_LADDER, PROVIDERS


def render(provider: str) -> str:
    models = {m: perfsim.fit_machine(provider, m) for m in MACHINES}
    lines = [f"== Table ({provider}): latency s — paper / model ==",
             "NS    " + "".join(f"{m:>15s}" for m in MACHINES)]
    for ns in NS_LADDER:
        cells = []
        for m in MACHINES:
            paper = MEASURED[provider][m][ns][0]
            pred = float(models[m].predict_latency(ns))
            cells.append(f"{paper:6.1f}/{pred:6.1f} ")
        lines.append(f"{ns:<6d}" + "".join(cells))
    mapes = [models[m].mape for m in MACHINES]
    lines.append("MAPE  " + "".join(f"{x:>14.2f} " for x in mapes))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--provider", choices=PROVIDERS, default=None)
    args = ap.parse_args()
    for prov in ([args.provider] if args.provider else PROVIDERS):
        print(render(prov))
        print()


if __name__ == "__main__":
    main()
