"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (derived = the table's headline
quantity for that benchmark).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run --only kernels
  PYTHONPATH=src python -m benchmarks.run --json     # + BENCH_<name>.json
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: tiny shapes

``--json`` writes one ``BENCH_<name>.json`` per row (fields: name,
us_per_call, derived) so successive PRs leave a machine-readable perf
trajectory to diff against. ``--smoke`` runs every benchmark at reduced
shapes/iterations — the numbers are meaningless but every perf-path import
and compile is exercised (the CI rot check).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

SMOKE = False    # set by --smoke: tiny shapes, import/compile check only


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_tables_2_to_4() -> list:
    """Paper Tables 2-4: per-provider latency ladders. us_per_call times one
    full 21-machine model fit; derived = mean latency MAPE vs the paper's
    630 published cells (the reproduction fidelity number)."""
    from repro.core import perfsim
    us = _timeit(perfsim.fit_all, warmup=1, iters=3)
    summary = perfsim.validation_summary()
    rows = [("table2_4_perfsim_fit", us, f"mape={summary['mean_mape']:.3f}")]
    # per-provider derived values: worst machine MAPE
    for prov in ("AWS", "GCP", "Azure"):
        worst = max(v for k, v in summary["per_machine_mape"].items()
                    if k.startswith(prov))
        rows.append((f"table{2 + ['AWS', 'GCP', 'Azure'].index(prov)}"
                     f"_{prov.lower()}_ladder", us / 3,
                     f"worst_mape={worst:.3f}"))
    return rows


def bench_table5_cost() -> list:
    """Paper Table 5: cost analysis. derived = overall GPU/CPU cost ratio
    (paper headline: '300% more' ~ measured 2.5x)."""
    from repro.core import costmodel
    us = _timeit(costmodel.gpu_cost_premium, iters=10)
    prem = costmodel.gpu_cost_premium()
    rows = [("table5_gpu_premium", us, f"ratio={prem['overall']:.3f}")]
    us2 = _timeit(costmodel.cost_per_million_sentences, iters=10)
    cpm = costmodel.cost_per_million_sentences()
    best = min((v, f"{p}/{m}") for p, d in cpm.items()
               for m, v in d.items())
    rows.append(("table5_usd_per_1m_sentences", us2,
                 f"best={best[1]}@{best[0]:.2f}"))
    return rows


def bench_findings() -> list:
    """§4 findings validation (the paper's headline claims)."""
    from repro.core import analysis
    t0 = time.perf_counter()
    f = analysis.all_findings()
    us = (time.perf_counter() - t0) * 1e6
    n_hold = sum(1 for v in f.values()
                 if isinstance(v, dict) and v.get("holds"))
    return [("findings_validation", us, f"holds={n_hold}/5")]


def bench_kernels() -> list:
    """Pallas kernels (interpret mode on CPU — correctness-path timing) vs
    the XLA reference; derived = max |err| vs oracle."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.ref import flash_attention_ref, matmul_ref

    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    f = jax.jit(lambda a, b: ops.matmul(a, b))
    us = _timeit(lambda: jax.block_until_ready(f(x, w)))
    err = float(abs(np.asarray(f(x, w)) - np.asarray(matmul_ref(x, w))).max())
    rows.append(("kernel_cache_matmul_256", us, f"maxerr={err:.2e}"))

    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(3), (B, S, 2, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (B, S, 2, D), jnp.float32)
    fa = jax.jit(lambda a, b, c: ops.mha_prefill(a, b, c, bq=128, bk=128))
    us = _timeit(lambda: jax.block_until_ready(fa(q, k, v)))
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        k.transpose(0, 2, 1, 3).reshape(B * 2, S, D),
        v.transpose(0, 2, 1, 3).reshape(B * 2, S, D)).reshape(
            B, H, S, D).transpose(0, 2, 1, 3)
    err = float(abs(np.asarray(fa(q, k, v)) - np.asarray(ref)).max())
    rows.append(("kernel_flash_attention_256", us, f"maxerr={err:.2e}"))

    from repro.kernels.ref import rglru_scan_ref
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(5),
                                         (2, 256, 128)))
    bb = jax.random.normal(jax.random.PRNGKey(6), (2, 256, 128)) * 0.1
    fl = jax.jit(lambda x, y: ops.lru_scan(x, y, bs=128))
    us = _timeit(lambda: jax.block_until_ready(fl(a, bb)))
    err = float(abs(np.asarray(fl(a, bb))
                    - np.asarray(rglru_scan_ref(a, bb))).max())
    rows.append(("kernel_rglru_scan_256", us, f"maxerr={err:.2e}"))
    return rows


def bench_engine_ladder() -> list:
    """The POC itself (miniature): engine latency at NS=1 vs NS=16 —
    derived = the concurrency slowdown factor (the paper's core curve)."""
    import jax
    from repro.configs import get_config
    from repro.core.loadtest import run_ladder
    from repro.models import init_params
    from repro.serving import EngineConfig, ServingEngine

    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder", max_batch=8,
                                     pad_buckets=(32,)))
    try:
        sents = [np.random.randint(0, cfg.vocab_size, (16,))
                 for _ in range(64)]
        t0 = time.perf_counter()
        cells = run_ladder(eng, sents, ladder=(1, 16), repeats=1)
        us = (time.perf_counter() - t0) * 1e6
    finally:
        eng.close()
    slow = cells[1].latency_s / max(cells[0].latency_s, 1e-9)
    return [("engine_ladder_1_to_16", us, f"slowdown={slow:.2f}x")]


def bench_decode_hotpath() -> list:
    """PR 'fast decode hot path' before/after numbers: KV blocks visited by
    the block-skipping flash kernel (causal + windowed), engine decode
    latency with the fused scan+pool path vs the seed's per-token loop, and
    the per-batch cache-acquisition cost (pool reset-on-assign vs a fresh
    make_caches allocation sweep)."""
    import jax
    import jax.numpy as jnp
    from concurrent.futures import Future
    from repro.configs import get_config
    from repro.kernels.flash_attention import flash_attention
    from repro.models import init_params, make_caches
    from repro.serving import EngineConfig, ServingEngine
    from repro.serving.engine import _Request
    from repro.serving.kvcache import CachePool

    rows = []

    # --- kernel: fraction of KV blocks actually scored ------------------
    S, bq, bk = 512, 64, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (4, S, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, 32), jnp.float32)
    for label, window in (("causal", None), ("window64", 64)):
        fa = jax.jit(lambda a, b, c, w=window: flash_attention(
            a, b, c, causal=True, window=w, bq=bq, bk=bk,
            return_visits=True))
        us = _timeit(lambda: jax.block_until_ready(fa(q, k, v)[0]))
        vis = int(np.asarray(fa(q, k, v)[1]).sum()) // q.shape[0]
        total = (S // bq) * (S // bk)
        rows.append((f"flash_block_skip_{label}", us,
                     f"visited={vis}/{total}"))

    # --- engine: fused scan+pool decode vs seed per-token loop ----------
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    B, T = 4, (4 if SMOKE else 16)
    prompts = [rng.randint(0, cfg.vocab_size, (rng.randint(4, 20),))
               for _ in range(B)]

    def serve_once(eng):
        reqs = [_Request(np.asarray(p, np.int32), Future(),
                         time.perf_counter()) for p in prompts]
        eng._serve_batch(reqs)    # measure the serve path, not queue wait
        return [r.future.result() for r in reqs]

    timings = {}
    for label, scan, pool in (("loop", False, False),
                              ("scan_pool", True, True)):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=B, max_new_tokens=T,
            pad_buckets=(32,), use_scan_decode=scan, use_cache_pool=pool))
        try:
            timings[label] = _timeit(lambda: serve_once(eng), warmup=1,
                                     iters=5)
        finally:
            eng.close()
    speedup = timings["loop"] / timings["scan_pool"]
    rows.append(("engine_decode_loop_b4_t16", timings["loop"],
                 f"us_per_tok={timings['loop'] / (B * T):.0f}"))
    rows.append(("engine_decode_scan_b4_t16", timings["scan_pool"],
                 f"us_per_tok={timings['scan_pool'] / (B * T):.0f};"
                 f"speedup={speedup:.2f}x"))

    # --- memory: per-batch cache acquisition ----------------------------
    L = 32 + T
    us_alloc = _timeit(lambda: jax.block_until_ready(
        jax.tree.leaves(make_caches(cfg, B, L, dtype=jnp.float32))[0]),
        warmup=1, iters=10)
    cpool = CachePool(cfg, B, L, dtype=jnp.float32)

    def pool_acquire():
        slots, view = cpool.acquire(range(B))
        cpool.release_many(slots)
        return jax.block_until_ready(jax.tree.leaves(view)[0])

    us_pool = _timeit(pool_acquire, warmup=1, iters=10)
    rows.append(("cache_acquire_make_caches", us_alloc, f"batch={B}"))
    rows.append(("cache_acquire_pool", us_pool,
                 f"ratio={us_alloc / max(us_pool, 1e-9):.2f}x"))
    return rows


def bench_continuous_batching() -> list:
    """Staggered-arrival (open-loop) serving with heterogeneous per-request
    token budgets: p95 per-request latency and tokens/s, step-level
    continuous batching vs batch-at-a-time at the same offered load. The
    arrival gap is a fraction of one full decode so requests land
    mid-decode; budgets are mixed (short and long requests) — the regime
    step granularity exists for: a mid-decode arrival joins the in-flight
    batch instead of queueing behind it, and a short row retires (freeing
    its slot) the step it finishes instead of riding out the batch's full
    max_new_tokens. derived = p95 latency + throughput; the continuous row
    also reports its p95 speedup over the batch row."""
    import jax
    import jax.numpy as jnp
    from concurrent.futures import Future
    from repro.configs import get_config
    from repro.core.loadtest import run_staggered
    from repro.models import init_params
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    from repro.serving.engine import _Request

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    MB, BUCKET = 4, 16
    T = 16 if SMOKE else 64
    n_req = 8 if SMOKE else 24
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, (rng.randint(4, 14),))
               for _ in range(n_req)]
    # requests mostly stop well before the engine cap (the real-serving
    # shape: eos fires early) — batch-at-a-time still decodes T steps for
    # every batch, step-level stops each row at its own budget
    budgets = [int(b) for b in rng.randint(T // 8, T // 2 + 1, n_req)]
    sampling = [SamplingParams(max_new_tokens=b) for b in budgets]

    def warm(eng, continuous):
        """Compile every shape the run can hit, deterministically — a
        mid-run jit compile would swamp the scheduling effect."""
        if continuous:
            pool = eng._get_pool(BUCKET)
            pf = eng._prefill_fn()
            for B in range(1, MB + 1):       # prefill-into-slot per join size
                slots, view = pool.acquire([f"w{B}.{i}" for i in range(B)],
                                           gather=True)
                tks = jnp.zeros((B, BUCKET), jnp.int32)
                lns = jnp.full((B,), 5, jnp.int32)
                tok, caches = pf(eng.params, tks, lns, view,
                                 None, None, None)
                pool.write_back(slots, caches)
                jax.block_until_ready(tok)
                pool.release_many(slots)
        else:
            for B in range(1, MB + 1):       # fused serve per batch size
                eng._serve_batch([
                    _Request(np.asarray(prompts[i % n_req], np.int32),
                             Future(), time.perf_counter())
                    for i in range(B)])
        # end-to-end worker path (continuous: + the decode segment fn);
        # median of 3 so the load knob derived from it is stable vs noise
        serve = [eng.generate(prompts[0]).result(timeout=600).timing.total_s
                 for _ in range(3)]
        return float(np.median(serve))

    def measure(continuous, gap_s=None):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=MB, max_new_tokens=T,
            pad_buckets=(BUCKET,), decode_segment=8, continuous=continuous))
        try:
            one_req_s = warm(eng, continuous)
            if gap_s is None:
                # ~2.5 arrivals per full-budget decode: the backlog regime
                # where per-request budgets decide capacity (batch-at-a-
                # time pays T steps for every request; step-level retires
                # rows at their own budget)
                gap_s = one_req_s / 2.5
            best = None
            for _ in range(3):               # best-of-3 vs host noise
                eng.discard_samples()
                r = run_staggered(eng, prompts, gap_s=gap_s,
                                  sampling=sampling)
                if best is None or r.latency_p95_s < best.latency_p95_s:
                    best = r
        finally:
            eng.close()
        return best, gap_s

    batch, gap = measure(False)          # the same offered load for both
    cont, _ = measure(True, gap_s=gap)
    rows = [("continuous_batching_batch", batch.wall_s * 1e6,
             f"p95={batch.latency_p95_s:.3f}s;"
             f"tok_s={batch.tokens_per_s:.1f}"),
            ("continuous_batching_cont", cont.wall_s * 1e6,
             f"p95={cont.latency_p95_s:.3f}s;"
             f"tok_s={cont.tokens_per_s:.1f};"
             f"p95_speedup={batch.latency_p95_s / cont.latency_p95_s:.2f}x")]
    return rows


def bench_multi_bucket() -> list:
    """Mixed-bucket staggered arrivals: per-request p95 latency with
    per-bucket lanes vs the legacy single-set scheduler at the same
    offered load. The workload is the shape the paper's corpus has —
    a stream of short interactive requests (bucket 32) with occasional
    long-decode requests in another bucket (16). The single-set baseline
    recreates the cross-bucket head-of-line cliff: every interactive
    request arriving during a long decode waits for that set to drain,
    so the interactive tail inflates to the long request's service time;
    lanes admit them into their own bucket's free slots immediately. The
    long requests themselves decode slower under lanes (their segments
    round-robin with the busy interactive lane — the fixed-width-segment
    occupancy trade the ROADMAP tracks), which is why p95 is taken over
    the workload including the interactive tail, not the max. derived =
    p95 + throughput; the lanes row also reports its p95 speedup."""
    import jax
    from repro.configs import get_config
    from repro.core.loadtest import run_staggered
    from repro.models import init_params
    from repro.serving import EngineConfig, SamplingParams, ServingEngine

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    MB, BUCKETS = 4, (16, 32)
    T = 24 if SMOKE else 64              # long-request budget (the hog)
    n_req = 12 if SMOKE else 40
    hog_every = n_req // 2 if SMOKE else 20
    rng = np.random.default_rng(7)
    prompts, sampling = [], []
    for i in range(n_req):
        if i % hog_every == hog_every // 2:   # rare long decode, bucket 16
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(4, 17)),)))
            sampling.append(SamplingParams(max_new_tokens=T))
        else:                                 # interactive, bucket 32
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(17, 33)),)))
            sampling.append(SamplingParams(max_new_tokens=4))

    def measure(multi_lane, gap_s=None):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=MB, max_new_tokens=T,
            pad_buckets=BUCKETS, decode_segment=4, multi_lane=multi_lane))
        try:
            eng.warmup()     # every bucket: join sizes + segments
            serve = [eng.generate(prompts[0],
                                  SamplingParams(max_new_tokens=4)).result(
                timeout=600).timing.total_s for _ in range(3)]
            if gap_s is None:
                # one interactive arrival per interactive service time:
                # the regime where a long decode in the other bucket
                # otherwise traps a train of interactive requests
                gap_s = float(np.median(serve))
            best = None
            for _ in range(3):               # best-of-3 vs host noise
                eng.discard_samples()
                r = run_staggered(eng, prompts, gap_s=gap_s,
                                  sampling=sampling)
                if best is None or r.latency_p95_s < best.latency_p95_s:
                    best = r
        finally:
            eng.close()
        return best, gap_s

    single, gap = measure(False)         # the same offered load for both
    lanes, _ = measure(True, gap_s=gap)
    return [("multi_bucket_single", single.wall_s * 1e6,
             f"p95={single.latency_p95_s:.3f}s;"
             f"tok_s={single.tokens_per_s:.1f}"),
            ("multi_bucket_lanes", lanes.wall_s * 1e6,
             f"p95={lanes.latency_p95_s:.3f}s;"
             f"tok_s={lanes.tokens_per_s:.1f};"
             f"p95_speedup="
             f"{single.latency_p95_s / lanes.latency_p95_s:.2f}x")]


def bench_segment_width() -> list:
    """Occupancy-adaptive decode-segment widths vs always-max_batch, on the
    bench_multi_bucket staggered scenario (interactive bucket-32 stream +
    rare long bucket-16 decodes) at the same offered load. Under
    ``segment_width='fixed'`` the long request decodes at width max_batch
    even though it is alone in its lane — the occupancy trade
    bench_multi_bucket exposed; 'adaptive' compacts each lane's segment to
    the smallest power-of-two tier that fits its live rows, so the lone
    long request runs width-1/2 segments (and the interactive lane's
    segments shrink too, cutting the long class's round-robin waits).
    derived = the long-request class's decode-phase mean latency (the
    quantity the ROADMAP flagged) + workload p95/tok_s; the adaptive row
    adds its long-class speedup and a greedy token-identity check against
    the fixed run."""
    import jax
    from repro.configs import get_config
    from repro.core.loadtest import run_staggered
    from repro.models import init_params
    from repro.serving import EngineConfig, SamplingParams, ServingEngine

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    MB, BUCKETS = (4 if SMOKE else 8), (16, 32)
    T = 24 if SMOKE else 64              # long-request budget (the hog)
    n_req = 12 if SMOKE else 40
    hog_every = n_req // 2 if SMOKE else 20
    rng = np.random.default_rng(7)
    prompts, sampling, hogs = [], [], []
    for i in range(n_req):
        if i % hog_every == hog_every // 2:   # rare long decode, bucket 16
            hogs.append(i)
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(4, 17)),)))
            sampling.append(SamplingParams(max_new_tokens=T))
        else:                                 # interactive, bucket 32
            prompts.append(rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(17, 33)),)))
            sampling.append(SamplingParams(max_new_tokens=4))

    def measure(width_mode, gap_s=None):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=MB, max_new_tokens=T,
            pad_buckets=BUCKETS, decode_segment=4,
            segment_width=width_mode))
        try:
            eng.warmup()     # every bucket x join size x width tier
            serve = [eng.generate(prompts[0],
                                  SamplingParams(max_new_tokens=4)).result(
                timeout=600).timing.total_s for _ in range(3)]
            if gap_s is None:
                # one interactive arrival per interactive service time —
                # the interactive lane stays busy while the hog decodes
                gap_s = float(np.median(serve))
            best = None
            for _ in range(3):               # best-of-3 vs host noise
                r = run_staggered(eng, prompts, gap_s=gap_s,
                                  sampling=sampling, keep_results=True)
                cand = {                     # per-class split from the
                    "long_dec": float(np.mean(        # per-request results
                        [r.results[i].timing.decode_s for i in hogs])),
                    "p95": r.latency_p95_s,
                    "wall": r.wall_s,
                    "tok_s": r.tokens_per_s,
                    "tokens": [x.tokens.tolist() for x in r.results]}
                if best is None or cand["long_dec"] < best["long_dec"]:
                    best = cand
        finally:
            eng.close()
        return best, gap_s

    fixed, gap = measure("fixed")        # the same offered load for both
    adaptive, _ = measure("adaptive", gap_s=gap)
    identical = fixed["tokens"] == adaptive["tokens"]
    return [("segment_width_fixed", fixed["wall"] * 1e6,
             f"long_decode_mean={fixed['long_dec']:.3f}s;"
             f"p95={fixed['p95']:.3f}s;tok_s={fixed['tok_s']:.1f}"),
            ("segment_width_adaptive", adaptive["wall"] * 1e6,
             f"long_decode_mean={adaptive['long_dec']:.3f}s;"
             f"p95={adaptive['p95']:.3f}s;tok_s={adaptive['tok_s']:.1f};"
             f"long_decode_speedup="
             f"{fixed['long_dec'] / max(adaptive['long_dec'], 1e-9):.2f}x;"
             f"tokens_identical={identical}")]


def bench_prefix_cache() -> list:
    """Shared-prompt KV reuse: staggered streams that all resend one long
    system prompt plus a short unique suffix — the traffic shape whose
    prefill cost the prefix store amortizes — with ``prefix_cache`` off vs
    on at the same offered load. A warm hit claims a lane slot, gathers the
    stored KV into it in one fused load, and prefills only the suffix
    chunk, so the warm-request prefill mean (every request after the first;
    the first populates the store) is the quantity the store exists to cut.
    derived = warm prefill mean + p95/tok_s; the on row adds its warm
    prefill speedup, the lane hit/miss counters, a greedy token-identity
    check against the off run, and the measured window's jit-compile count
    (must be 0: warm hits at arbitrary matched offsets re-use the chunk
    program, never specialize)."""
    import jax
    from repro.configs import get_config
    from repro.core.loadtest import run_staggered
    from repro.models import init_params
    from repro.serving import EngineConfig, SamplingParams, ServingEngine

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    BUCKET = 32 if SMOKE else 128
    CHUNK = BUCKET // 4
    T = 4 if SMOKE else 8
    n_req = 6 if SMOKE else 12
    rng = np.random.default_rng(11)
    # system prompt fills 3/4 of the bucket; suffixes stay under one chunk
    # so every warm request prefills exactly one chunk instead of the
    # whole prompt
    sysprompt = rng.integers(0, cfg.vocab_size, (BUCKET * 3 // 4,))
    lo, hi = (2, 6) if SMOKE else (4, 12)
    prompts = [np.concatenate([
        sysprompt, rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(lo, hi + 1)),))])
        for _ in range(n_req)]
    sampling = [SamplingParams(max_new_tokens=T) for _ in range(n_req)]

    def measure(prefix_cache, gap_s=None):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=4, max_new_tokens=T,
            pad_buckets=(BUCKET,), decode_segment=2, prefill_chunk=CHUNK,
            prefix_cache=prefix_cache))
        try:
            eng.warmup()
            serve = [eng.generate(prompts[0], SamplingParams(
                max_new_tokens=T)).result(timeout=600).timing.total_s
                for _ in range(3)]
            if gap_s is None:
                # ~2 arrivals per full service time: requests overlap, so
                # warm hits join mid-flight the way shared-prompt traffic
                # actually lands
                gap_s = float(np.median(serve)) / 2
            best = None
            for _ in range(3):               # best-of-3 vs host noise
                eng.window()                 # counters cover this run only
                r = run_staggered(eng, prompts, gap_s=gap_s,
                                  sampling=sampling, keep_results=True)
                win = eng.window()
                lanes = win.get("lanes", {})
                cand = {
                    "warm_prefill": float(np.mean(
                        [x.timing.prefill_s for x in r.results[1:]])),
                    "p95": r.latency_p95_s, "wall": r.wall_s,
                    "tok_s": r.tokens_per_s,
                    "compiles": win.get("jit_compiles", -1),
                    "hits": sum(s.get("prefix_hits", 0)
                                for s in lanes.values()),
                    "misses": sum(s.get("prefix_misses", 0)
                                  for s in lanes.values()),
                    "tokens": [x.tokens.tolist() for x in r.results]}
                if (best is None
                        or cand["warm_prefill"] < best["warm_prefill"]):
                    best = cand
        finally:
            eng.close()
        return best, gap_s

    off, gap = measure(False)            # the same offered load for both
    on, _ = measure(True, gap_s=gap)
    identical = off["tokens"] == on["tokens"]
    return [("prefix_cache_off", off["wall"] * 1e6,
             f"warm_prefill_mean={off['warm_prefill'] * 1e3:.2f}ms;"
             f"p95={off['p95']:.3f}s;tok_s={off['tok_s']:.1f}"),
            ("prefix_cache_on", on["wall"] * 1e6,
             f"warm_prefill_mean={on['warm_prefill'] * 1e3:.2f}ms;"
             f"p95={on['p95']:.3f}s;tok_s={on['tok_s']:.1f};"
             f"warm_prefill_speedup="
             f"{off['warm_prefill'] / max(on['warm_prefill'], 1e-9):.2f}x;"
             f"hits={on['hits']};misses={on['misses']};"
             f"window_compiles={on['compiles']};"
             f"tokens_identical={identical}")]


def _train_lm(cfg, steps, data, seed=0):
    """Brief deterministic training of ``cfg`` on ``data``'s batches —
    returns the trained params (fresh init from ``seed``)."""
    import jax
    import jax.numpy as jnp
    from repro.models import init_params
    from repro.training import OptConfig, adamw_init, train_step

    params = init_params(cfg, jax.random.PRNGKey(seed))
    oc = OptConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    state = adamw_init(params)
    step_fn = jax.jit(lambda p, s, b: train_step(cfg, oc, p, s, b))
    for batch in data.batches(steps):
        params, state, _ = step_fn(params, state,
                                   {"tokens": jnp.asarray(batch["tokens"])})
    return params


def _trained_smoke_lm(steps=60):
    """qwen2-0.5b smoke briefly trained on the synthetic phrase corpus.

    The quant benches measure token drift against the bf16 baseline, and a
    random-init model's logit margins are near-ties — any perturbation
    flips argmax, so agreement there measures init noise, not
    quantization. A minute of training on SyntheticLM's recurring phrases
    gives trained-scale margins (median top-2 gap grows ~4x), which is the
    regime the paper's deployments serve in. Deterministic (fixed seeds).
    Returns (cfg, params, data)."""
    from repro.configs import get_config
    from repro.training.data import DataConfig, SyntheticLM

    cfg = get_config("qwen2-0.5b", smoke=True)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  batch_size=16, seed=0))
    params = _train_lm(cfg, steps, data, seed=0)
    return cfg, params, data


def bench_quant() -> list:
    """int8 weights + int8 KV cache vs the bf16/f32 baseline.

    Two measurements on a briefly-trained smoke model (same engine config
    both arms, warmup before every measured window):

    * drift — teacher-forced greedy top-1 agreement: the quantized model
      decodes the bf16 arm's token stream (prefill + per-step decode
      through the int8 KV cache) and its per-step argmax is compared
      position-wise. Teacher forcing isolates per-step drift from the
      cascade a single early flip causes in free-running generation.
    * footprint + serving — a staggered load at identical offered rate,
      off vs on; footprint = (weight_bytes + lane kv_bytes) ratio from the
      engine gauges, and the measured window must stay compile-clean
      (``window_compiles=0``) in both arms: warmup primes the quantized
      variants, nothing specializes mid-measurement.

    derived: off row = footprint bytes + p95/tok_s; on row adds
    footprint_ratio, top1_agreement (and drift_ok, the >= 0.99 bound CI
    greps), and both arms' window compile counts."""
    import jax
    import jax.numpy as jnp
    from repro.core.loadtest import run_staggered
    from repro.models import decode_step, make_caches, prefill
    from repro.quant import quantize_params
    from repro.serving import EngineConfig, SamplingParams, ServingEngine

    cfg, params, data = _trained_smoke_lm()
    qparams = quantize_params(params)

    # ---- drift: teacher-forced per-step top-1 agreement
    B, T_drift, Lp = 8, 16, 24
    dr = np.random.default_rng(1)
    data.rng = dr                       # decouple from training draws
    prompts_d = np.stack([data._doc(Lp) for _ in range(B)]).astype(np.int32)

    def forced(p, kv_quant, teacher=None):
        caches = make_caches(cfg, B, Lp + T_drift, dtype=jnp.float32,
                             kv_quant=kv_quant)
        logits, caches, _ = prefill(cfg, p, jnp.asarray(prompts_d), caches)
        preds = [np.asarray(jnp.argmax(logits[:, -1], -1))]
        pos = jnp.full((B,), Lp, jnp.int32)
        for t in range(T_drift - 1):
            tok = jnp.asarray(preds[t] if teacher is None else teacher[:, t])
            logits, caches, _ = decode_step(cfg, p, tok[:, None],
                                            pos[:, None], caches)
            preds.append(np.asarray(jnp.argmax(logits[:, 0], -1)))
            pos = pos + 1
        return np.stack(preds, 1)

    base_tok = forced(params, None)
    agreement = float((forced(qparams, "int8", base_tok) == base_tok).mean())

    # ---- footprint + serving A/B
    BUCKET = 32 if SMOKE else 128
    T = 4 if SMOKE else 16
    n_req = 6 if SMOKE else 12
    MB = 4 if SMOKE else 8
    rng = np.random.default_rng(13)
    data.rng = rng
    lo, hi = (BUCKET // 2, BUCKET - 2)
    prompts = [data._doc(int(rng.integers(lo, hi + 1))) for _ in range(n_req)]
    sampling = [SamplingParams(max_new_tokens=T) for _ in range(n_req)]

    def measure(quant, gap_s=None):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=MB, max_new_tokens=T,
            pad_buckets=(BUCKET,), decode_segment=2,
            prefill_chunk=BUCKET // 4,
            weight_quant="int8" if quant else None,
            kv_quant="int8" if quant else None))
        try:
            eng.warmup()
            serve = [eng.generate(prompts[0], SamplingParams(
                max_new_tokens=T)).result(timeout=600).timing.total_s
                for _ in range(3)]
            if gap_s is None:
                gap_s = float(np.median(serve)) / 2
            best, compiles = None, 0     # compiles: worst across all runs
            for _ in range(3):               # best-of-3 vs host noise
                eng.window()                 # counters cover this run only
                r = run_staggered(eng, prompts, gap_s=gap_s,
                                  sampling=sampling, keep_results=True)
                win = eng.window()
                m = eng.metrics()
                compiles = max(compiles, win.get("jit_compiles", -1))
                cand = {
                    "p95": r.latency_p95_s, "wall": r.wall_s,
                    "tok_s": r.tokens_per_s,
                    "weight_bytes": m["weight_bytes"],
                    "kv_bytes": sum(s.get("kv_bytes", 0)
                                    for s in m.get("lanes", {}).values())}
                if best is None or cand["p95"] < best["p95"]:
                    best = cand
            best["compiles"] = compiles
        finally:
            eng.close()
        return best, gap_s

    off, gap = measure(False)            # the same offered load for both
    on, _ = measure(True, gap_s=gap)
    foot_off = off["weight_bytes"] + off["kv_bytes"]
    foot_on = on["weight_bytes"] + on["kv_bytes"]
    ratio = foot_off / max(foot_on, 1)
    return [("quant_off", off["wall"] * 1e6,
             f"weight_bytes={off['weight_bytes']};"
             f"kv_bytes={off['kv_bytes']};"
             f"p95={off['p95']:.3f}s;tok_s={off['tok_s']:.1f};"
             f"window_compiles={off['compiles']}"),
            ("quant_on", on["wall"] * 1e6,
             f"weight_bytes={on['weight_bytes']};"
             f"kv_bytes={on['kv_bytes']};"
             f"p95={on['p95']:.3f}s;tok_s={on['tok_s']:.1f};"
             f"footprint_ratio={ratio:.2f}x;"
             f"top1_agreement={agreement:.4f};"
             f"drift_ok={agreement >= 0.99};"
             f"window_compiles={on['compiles']}")]


def bench_spec_decode() -> list:
    """Speculative decoding (draft-and-verify) vs plain continuous decode.

    Target = the briefly-trained qwen2-0.5b smoke model; draft = a 4x
    smaller single-layer model trained on the same synthetic phrase
    corpus (the regime speculation needs: the corpus is predictable
    enough that the draft's greedy continuations usually match the
    target's). Both arms serve the same greedy closed batch at
    bench_decode_hotpath shapes (B=4, prompt lens 4..20, T new tokens,
    bucket 32); the spec arm proposes ``spec_k`` draft tokens per round
    and the target verifies them in one chunked forward, committing the
    agreed prefix plus one corrected token.

    derived: off row = us/token; on row adds speedup (the acceptance
    criterion: >= 2x at temperature=0), the measured accept rate,
    tokens_identical (greedy spec decode must reproduce the plain arm's
    streams bit-for-bit — speculation is a latency optimization, never a
    sampler change) and window_compiles (must be 0: warmup primes the
    draft/verify/rollback variants)."""
    import dataclasses
    from repro.configs import get_config
    from repro.serving import EngineConfig, SamplingParams, ServingEngine
    from repro.training.data import DataConfig, SyntheticLM

    steps = 20 if SMOKE else 60
    cfg = get_config("qwen2-0.5b", smoke=True)
    if not SMOKE:
        # speculation pays when the target forward is flops-bound (the
        # draft's advantage is its 16x flops discount; at smoke width both
        # forwards sit on the dispatch-overhead floor and the discount
        # vanishes) — widen the target to the smallest shape where compute
        # dominates. Smoke keeps the stock width: CI only checks identity
        # and compile-cleanliness there, not the speedup.
        cfg = dataclasses.replace(cfg, d_model=640, n_heads=10,
                                  n_kv_heads=2, d_ff=1536)
    cfg = dataclasses.replace(cfg, name="qwen2-smoke-spec-target")
    dcfg = dataclasses.replace(cfg, name="qwen2-smoke-spec-draft",
                               n_layers=1, d_model=112, n_heads=7,
                               n_kv_heads=1, d_ff=256)
    dcf = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                     batch_size=16, seed=0)
    data = SyntheticLM(dcf)
    params = _train_lm(cfg, steps, data, seed=0)
    dparams = _train_lm(dcfg, steps, SyntheticLM(dcf), seed=1)

    B, T = 4, (8 if SMOKE else 16)
    rng = np.random.default_rng(5)
    data.rng = rng                      # decouple from training draws
    prompts = [data._doc(int(rng.integers(4, 20))) for _ in range(B)]
    sampling = [SamplingParams(max_new_tokens=T) for _ in range(B)]

    def measure(spec):
        eng = ServingEngine(cfg, params, EngineConfig(
            mode="decoder", max_batch=B, max_new_tokens=T,
            pad_buckets=(32,), decode_segment=8,
            spec_decode=spec, spec_k=7),
            draft=(dcfg, dparams) if spec else None)
        try:
            eng.warmup()
            eng.window()                # measured span starts compile-clean

            def serve():
                hs = [eng.generate(p, s) for p, s in zip(prompts, sampling)]
                return [h.result(timeout=600).tokens for h in hs]

            us = _timeit(serve, warmup=1, iters=1 if SMOKE else 5)
            toks = serve()
            win = eng.window()
            lanes = win.get("lanes", {})
            prop = sum(s.get("spec_proposed", 0) for s in lanes.values())
            acc = sum(s.get("spec_accepted", 0) for s in lanes.values())
        finally:
            eng.close()
        return {"us": us, "tokens": [t.tolist() for t in toks],
                "compiles": win.get("jit_compiles", -1),
                "accept": acc / prop if prop else 0.0}

    off = measure(False)
    on = measure(True)
    identical = off["tokens"] == on["tokens"]
    speedup = off["us"] / max(on["us"], 1e-9)
    return [("spec_decode_off", off["us"],
             f"us_per_tok={off['us'] / (B * T):.0f};"
             f"window_compiles={off['compiles']}"),
            ("spec_decode_on", on["us"],
             f"us_per_tok={on['us'] / (B * T):.0f};"
             f"speedup={speedup:.2f}x;"
             f"accept_rate={on['accept']:.3f};"
             f"tokens_identical={identical};"
             f"window_compiles={on['compiles']}")]


def bench_deploy_lab() -> list:
    """Deployment-lab harness: one profile x one ladder scenario through
    ExperimentRunner + drift_report. us_per_call times the whole grid;
    derived = records emitted + findings ledger coverage (must list every
    paper finding) — the rot check for the experiment subsystem."""
    import jax
    from repro.configs import get_config
    from repro.deploy.profiles import profile
    from repro.deploy.report import PAPER_FINDINGS, drift_report
    from repro.deploy.runner import ExperimentRunner, WorkloadScenario
    from repro.models import init_params
    from repro.serving import EngineConfig, ServingEngine

    cfg = get_config("gector-base", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def factory(scenario):
        eng = ServingEngine(cfg, params,
                            EngineConfig(mode="encoder", max_batch=4,
                                         pad_buckets=(32,)))
        rng = np.random.RandomState(0)
        sents = [rng.randint(0, cfg.vocab_size, (16,)) for _ in range(32)]
        return eng, sents, None

    scenario = WorkloadScenario(name="bench", ladder=(1, 2), repeats=1)
    runner = ExperimentRunner(factory)
    t0 = time.perf_counter()
    records = runner.run_grid([profile("AWS", "C")], [scenario])
    report = drift_report(records)
    us = (time.perf_counter() - t0) * 1e6
    listed = sum(1 for k in PAPER_FINDINGS if k in report["findings"])
    return [("deploy_lab_grid", us,
             f"records={len(records)};"
             f"findings={listed}/{len(PAPER_FINDINGS)}")]


def bench_roofline_summary() -> list:
    """Dry-run roofline (from benchmarks/dryrun_single_pod.json if present);
    derived = count of pairs by dominant term."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "dryrun_single_pod.json")
    if not os.path.exists(path):
        return [("roofline_summary", 0.0,
                 "no dryrun json (run dryrun --all)")]
    t0 = time.perf_counter()
    with open(path) as f:
        data = json.load(f)
    doms = {}
    for r in data["results"]:
        if "roofline" in r:
            doms[r["roofline"]["dominant"]] = \
                doms.get(r["roofline"]["dominant"], 0) + 1
    us = (time.perf_counter() - t0) * 1e6
    return [("roofline_summary", us,
             ";".join(f"{k}={v}" for k, v in sorted(doms.items())))]


ALL = {
    "tables_2_to_4": bench_tables_2_to_4,
    "table5": bench_table5_cost,
    "findings": bench_findings,
    "kernels": bench_kernels,
    "engine": bench_engine_ladder,
    "decode_hotpath": bench_decode_hotpath,
    "continuous_batching": bench_continuous_batching,
    "multi_bucket": bench_multi_bucket,
    "segment_width": bench_segment_width,
    "prefix_cache": bench_prefix_cache,
    "quant": bench_quant,
    "spec_decode": bench_spec_decode,
    "deploy_lab": bench_deploy_lab,
    "roofline": bench_roofline_summary,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(ALL), default=None)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json per row (perf trajectory)")
    ap.add_argument("--json-dir", default=".",
                    help="directory for --json output files")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes/iterations: exercise every perf-path "
                         "import and compile without the full timings")
    args = ap.parse_args()
    if args.smoke:
        global SMOKE
        SMOKE = True
    names = [args.only] if args.only else list(ALL)
    print("name,us_per_call,derived")
    ok = True
    for n in names:
        try:
            rows = ALL[n]()
            if not rows:
                raise RuntimeError("benchmark returned no rows")
        except Exception as e:  # noqa: BLE001
            # a failed/empty run writes no JSON: the BENCH_* files are the
            # perf trajectory across PRs, and clobbering a good datapoint
            # with nothing would erase it from the diff
            ok = False
            print(f"{n},nan,ERROR:{e}", file=sys.stderr)
            if args.json:
                print(f"{n}: wrote no BENCH_*.json — any existing "
                      f"datapoints for this benchmark are preserved",
                      file=sys.stderr)
            continue
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
            if args.json:
                path = os.path.join(args.json_dir, f"BENCH_{row[0]}.json")
                with open(path, "w") as f:
                    json.dump({"name": row[0],
                               "us_per_call": round(row[1], 1),
                               "derived": row[2]}, f, indent=2)
                    f.write("\n")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
