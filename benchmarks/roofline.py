"""Roofline report generator — renders EXPERIMENTS.md §Roofline from the
dry-run JSON produced by ``repro.launch.dryrun --all --out ...``.

  PYTHONPATH=src python -m benchmarks.roofline \
      --json benchmarks/dryrun_single_pod.json --md
"""
from __future__ import annotations

import argparse
import json


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    return f"{x*1e3:7.2f}ms"


def render(data: dict, md: bool = False) -> str:
    lines = []
    if md:
        lines.append("| arch | shape | compute | memory | collective | "
                     "dominant | useful-FLOPs | peak GiB/dev | fits 16G |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in data["results"]:
        if r.get("skipped"):
            if md:
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"skipped | — | — | — |")
            else:
                lines.append(f"{r['arch']:24s} {r['shape']:12s} SKIPPED: "
                             f"{r['skipped']}")
            continue
        t = r["roofline"]
        pd = r["per_device"]
        # donation-adjusted peak: the CPU backend ignores donation, so the
        # donated state's output copy (params+opt / KV cache) is an artifact
        adj = pd.get("adjusted_peak_bytes",
                     pd["peak_bytes"] - min(pd.get("output_bytes", 0),
                                            pd.get("argument_bytes", 0)))
        peak = adj / 2**30
        fits = "yes" if peak <= 16.0 else "NO"
        if md:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
                f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
                f"{t['dominant'].replace('_s','')} | "
                f"{t['useful_flops_ratio']:.2f} | {peak:.2f} | {fits} |")
        else:
            lines.append(
                f"{r['arch']:24s} {r['shape']:12s} "
                f"C={_fmt_s(t['compute_s'])} M={_fmt_s(t['memory_s'])} "
                f"X={_fmt_s(t['collective_s'])} dom={t['dominant']:13s} "
                f"useful={t['useful_flops_ratio']:.2f} peak={peak:.1f}GiB")
    if data.get("failures"):
        lines.append("")
        for f in data["failures"]:
            lines.append(f"FAILED {f['arch']} x {f['shape']}: "
                         f"{f['error'][:160]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="benchmarks/dryrun_single_pod.json")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    with open(args.json) as f:
        data = json.load(f)
    print(render(data, md=args.md))


if __name__ == "__main__":
    main()
