#!/usr/bin/env python
"""repro-lint: run the JAX-aware static-analysis passes over src/.

    PYTHONPATH=src python tools/lint.py [root] [--strict] \
        [--select PASS[,PASS]] [--baseline FILE] [--list-passes]

Pure stdlib + ``repro.analysis`` (which imports no jax): CI runs this
without an accelerator stack. Exit status is 0 when no unsuppressed
findings remain; ``--strict`` additionally fails on baseline-hygiene
problems — malformed or justification-less entries, and entries that no
longer suppress anything (stale once the code is fixed).

See docs/ANALYSIS.md for the pass catalog, the ``# guarded-by:`` /
``# holds:`` annotation syntax, and the baseline workflow.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
if str(_REPO / "src") not in sys.path:
    sys.path.insert(0, str(_REPO / "src"))

from repro.analysis import (Baseline, PASSES, load_modules,  # noqa: E402
                            run_passes)

DEFAULT_BASELINE = _REPO / "tools" / "lint_baseline.txt"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="lint.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("root", nargs="?", default=str(_REPO),
                    help="repo root to lint (default: this repo); "
                         "src/ under it is analysed")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on baseline-hygiene problems "
                         "(malformed/unjustified/stale entries)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="PASS[,PASS]",
                    help="run only these passes (repeatable)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    metavar="FILE",
                    help="suppression file (default: tools/"
                         "lint_baseline.txt); 'none' disables")
    ap.add_argument("--list-passes", action="store_true",
                    help="list registered passes and exit")
    args = ap.parse_args(argv)

    if args.list_passes:
        for name in sorted(PASSES):
            print(f"{name:12s} {PASSES[name].description}")
        return 0

    select = None
    if args.select:
        select = [p for chunk in args.select for p in chunk.split(",") if p]

    modules = load_modules(Path(args.root))
    try:
        findings = run_passes(modules, select=select)
    except ValueError as e:          # unknown --select name
        ap.error(str(e))

    baseline = Baseline() if args.baseline == "none" \
        else Baseline.load(Path(args.baseline))
    kept = baseline.filter(findings)

    for f in kept:
        print(f.render())

    failures = len(kept)
    suppressed = len(findings) - len(kept)
    if args.strict:
        for err in baseline.errors:
            print(f"baseline error: {err}")
            failures += 1
        for e in baseline.unused():
            print(f"baseline stale: {args.baseline}:{e.lineno}: entry "
                  f"`{e.pass_id} | {e.path} | {e.scope} | {e.detail}` "
                  f"suppressed nothing — remove it")
            failures += 1

    ran = ", ".join(select) if select else "all passes"
    print(f"repro-lint: {len(modules)} modules, {ran}: "
          f"{len(kept)} finding(s), {suppressed} suppressed"
          + (f", {failures - len(kept)} baseline problem(s)"
             if args.strict and failures > len(kept) else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
