"""Docs drift guard (CI `docs` job; also run by tests/test_docs.py).

Three cheap checks that keep the docs from rotting as the code moves:

  1. every relative markdown link in README.md, ROADMAP.md and docs/*.md
     points at a path that exists in the repo;
  2. every ``EngineConfig`` field name appears in docs/TUNING.md (the
     knob-by-knob tuning guide must cover new knobs the moment they are
     added);
  3. every registered repro-lint pass is documented in docs/ANALYSIS.md
     (pass names are read from ``repro.analysis`` — itself jax-free).

Pure stdlib (the EngineConfig fields are read via ``ast``, not import),
so the CI job needs no jax. Exit code 0 = clean; 1 = drift, with one
line per problem.

  python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

DOC_FILES = ("README.md", "ROADMAP.md")   # + every docs/*.md
ENGINE_PY = Path("src/repro/serving/engine.py")
TUNING_MD = Path("docs/TUNING.md")
ANALYSIS_MD = Path("docs/ANALYSIS.md")

# [text](target) — markdown links, excluding images; target split at '#'
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def doc_paths(root: Path):
    for name in DOC_FILES:
        if (root / name).exists():
            yield root / name
    yield from sorted((root / "docs").glob("*.md"))


def check_links(root: Path) -> list:
    """Relative markdown links must resolve (against the doc's directory,
    like a reader clicking them would)."""
    problems = []
    for doc in doc_paths(root):
        for target in _LINK.findall(doc.read_text()):
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            if not (doc.parent / path).exists():
                problems.append(f"{doc.relative_to(root)}: broken link "
                                f"-> {target}")
    return problems


def engine_config_fields(root: Path) -> list:
    """EngineConfig's dataclass field names, parsed without importing."""
    tree = ast.parse((root / ENGINE_PY).read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineConfig":
            return [s.target.id for s in node.body
                    if isinstance(s, ast.AnnAssign)
                    and isinstance(s.target, ast.Name)]
    raise AssertionError(f"EngineConfig not found in {ENGINE_PY}")


def check_tuning_covers_config(root: Path) -> list:
    tuning = (root / TUNING_MD).read_text()
    return [f"{TUNING_MD}: EngineConfig field {name!r} is undocumented"
            for name in engine_config_fields(root)
            if not re.search(rf"`{re.escape(name)}`", tuning)]


def lint_pass_names(root: Path) -> list:
    """Registered repro-lint pass names, via the analysis registry
    (pure stdlib — importing repro.analysis pulls in no jax)."""
    src = str(root / "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.analysis import PASSES
    return sorted(PASSES)


def check_analysis_docs(root: Path) -> list:
    """docs/ANALYSIS.md must document every registered pass by name."""
    md = root / ANALYSIS_MD
    if not md.exists():
        return [f"{ANALYSIS_MD}: missing (the repro-lint pass catalog)"]
    text = md.read_text()
    return [f"{ANALYSIS_MD}: lint pass {name!r} is undocumented"
            for name in lint_pass_names(root)
            if not re.search(rf"`{re.escape(name)}`", text)]


def main(argv=None) -> int:
    root = Path((argv or sys.argv[1:] or ["."])[0]).resolve()
    problems = (check_links(root) + check_tuning_covers_config(root)
                + check_analysis_docs(root))
    for p in problems:
        print(f"docs-drift: {p}")
    if not problems:
        n_docs = len(list(doc_paths(root)))
        n_fields = len(engine_config_fields(root))
        n_passes = len(lint_pass_names(root))
        print(f"docs clean: {n_docs} files link-checked, "
              f"{n_fields} EngineConfig fields covered by {TUNING_MD}, "
              f"{n_passes} lint passes covered by {ANALYSIS_MD}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
