"""End-to-end training driver — the paper's model (GECToR) trained on the
synthetic NUCLE-statistics corpus for a few hundred steps, with tag-level
F0.5 evaluation and checkpointing.

  PYTHONPATH=src python examples/train_gector.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.corpus import CorpusConfig, GECCorpus
from repro.core.gector import (gector_loss, init_gector, iterative_correct,
                               predict_tags)
from repro.core.tags import edit_f_beta
from repro.training.checkpoint import save
from repro.training.optimizer import OptConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--train-error-rate", type=float, default=0.4,
                    help="synthetic-pretraining error rate (GECToR trains "
                         "on dense synthetic errors, evals on sparse)")
    ap.add_argument("--ckpt", default="/tmp/gector_small.ckpt")
    args = ap.parse_args()

    cfg = get_config("gector-base", smoke=True)
    train_corpus = GECCorpus(CorpusConfig(
        vocab_size=cfg.vocab_size, edit_words=256,
        error_rate=args.train_error_rate, seed=0))
    vocab = train_corpus.vocab
    params = init_gector(cfg, jax.random.PRNGKey(0), vocab)
    oc = OptConfig(lr=args.lr, warmup_steps=30, total_steps=args.steps,
                   weight_decay=0.01)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        (l, m), g = jax.value_and_grad(
            lambda pp: gector_loss(cfg, pp, b), has_aux=True)(p)
        p, o, gn = adamw_update(oc, p, g, o)
        return p, o, l, m

    t0 = time.time()
    for i, b in enumerate(train_corpus.batches(args.batch, args.seq,
                                               args.steps)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss, m = step(params, opt, batch)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"tag_acc {float(m['tag_acc']):.3f} "
                  f"edit_acc {float(m['edit_acc']):.3f} "
                  f"[{time.time()-t0:.0f}s]")

    # ---- eval on NUCLE-statistics test distribution (low error rate) ----
    test = GECCorpus(CorpusConfig(vocab_size=cfg.vocab_size, edit_words=256,
                                  error_rate=0.08, seed=99))
    b = next(test.batches(128, args.seq, 1))
    best = None
    for gate in (0.0, 0.3, 0.5, 0.7):
        pred = predict_tags(cfg, params, b["tokens"], b["mask"],
                            min_error_prob=gate)
        m = edit_f_beta(pred, b["tags"], b["mask"])
        print(f"detect-gate {gate}: P={m['precision']:.3f} "
              f"R={m['recall']:.3f} F0.5={m['f0.5']:.3f}")
        if best is None or m["f0.5"] > best[1]["f0.5"]:
            best = (gate, m)
    print(f"best gate {best[0]} -> F0.5 {best[1]['f0.5']:.3f} "
          f"(paper's reference GECToR: 0.653 on real CoNLL-2014)")

    # ---- iterative correction improves token match ----
    srcs, _, cleans = zip(*list(test.generate(64)))
    fixed = iterative_correct(cfg, params, vocab, srcs)

    def tok_match(a, b):
        L = min(len(a), len(b))
        return float(np.mean(np.asarray(a[:L]) == np.asarray(b[:L])))
    before = np.mean([tok_match(s, c) for s, c in zip(srcs, cleans)])
    after = np.mean([tok_match(f, c) for f, c in zip(fixed, cleans)])
    print(f"token match vs clean: before={before:.4f} after={after:.4f}")

    save(args.ckpt, {"params": params})
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
