"""Reproduce the paper's cross-cloud cost/performance study from its own
published measurements: fit the per-machine performance models, validate the
four headline findings, and print the cost tables (incl. the beyond-paper
US$/1M-sentences metric).

  PYTHONPATH=src python examples/cost_study.py
"""
import json

from repro.core import analysis, costmodel, perfsim
from repro.core.environments import MACHINES, PROVIDERS, instance


def main():
    print("== Table 5: monthly cost (US$) ==")
    print(f"{'':8s}" + "".join(f"{m:>9s}" for m in MACHINES))
    for prov in PROVIDERS:
        row = [instance(prov, m).monthly_cost_usd for m in MACHINES]
        print(f"{prov:8s}" + "".join(f"{v:9.2f}" for v in row))

    print("\n== GPU cost premium (paper: 'average cost 300% higher') ==")
    prem = costmodel.gpu_cost_premium()
    for k, v in prem.items():
        print(f"  {k:8s} GPU/CPU ratio = {v:.2f}x")
    print("  -> Table 5 arithmetic gives ~2.5x; the 300% headline is the "
          "paper's rounding of 'several-fold'. Both recorded.")

    print("\n== Machine C vs E (the cache finding) ==")
    for prov, sav in costmodel.machine_c_vs_e_saving().items():
        print(f"  {prov:6s} cost saving C vs E: {sav*100:5.1f}%")
    reg = perfsim.cpu_only_feature_regression()
    print(f"  CPU-only throughput regression (standardized): "
          f"{json.dumps({k: round(v, 3) for k, v in reg['coef'].items()})} "
          f"R2={reg['r2']:.2f}")

    print("\n== SLO capacity (max NS under 2 s) ==")
    cap = analysis.slo_capacity_table()
    print(f"{'':8s}" + "".join(f"{m:>6s}" for m in MACHINES))
    for prov in PROVIDERS:
        print(f"{prov:8s}" + "".join(f"{cap[prov][m]:6d}" for m in MACHINES))

    print("\n== Beyond-paper: US$ per 1M sentences at best SLO point ==")
    cpm = costmodel.cost_per_million_sentences()
    for prov in PROVIDERS:
        cells = " ".join(f"{m}:{cpm[prov][m]:7.2f}" for m in MACHINES)
        print(f"  {prov:6s} {cells}")
    print("  -> GPUs are 3-5x cheaper *per sentence* at full load — the "
          "paper's '300% more expensive' inverts once utilization is "
          "considered; its POC (low, bursty load) conclusion still holds.")

    print("\n== All findings ==")
    f = analysis.all_findings()
    for k, v in f.items():
        if isinstance(v, dict) and "holds" in v:
            print(f"  {k:28s} holds={v['holds']}")
    print(f"  perfsim mean MAPE over 210 latency cells: "
          f"{f['perfsim_fit']['mean_mape']:.3f}")


if __name__ == "__main__":
    main()
