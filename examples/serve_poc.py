"""The paper's POC, end to end on this host: deploy GECToR behind the
serving engine and run the 2^N concurrent-sentences ladder (Fig. 7),
producing a Tables-2-4-style latency/vCPU/RAM table — then repeat with the
admission-control queue the paper proposes in §4 and compare.

  PYTHONPATH=src python examples/serve_poc.py --max-ns 64 --repeats 2

--decoder-demo appends the serving-API-v2 walkthrough: a typed generation
request streamed token by token while a second request joins the in-flight
decode batch mid-stream (step-level continuous batching), with the
per-phase timing breakdown the paper's wall-clock tables can't see.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.corpus import CorpusConfig, GECCorpus
from repro.core.gector import init_gector
from repro.core.loadtest import format_table, run_ladder
from repro.core.tags import TagVocab
from repro.models import init_params
from repro.serving import EngineConfig, SamplingParams, ServingEngine


def decoder_demo():
    """Serving API v2 end to end: two pad buckets served as independent
    lanes, a sampled request streamed token by token, a long prompt
    chunk-prefilled into the other lane mid-stream, and decode segments
    compacted to each lane's live occupancy (width tiers)."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        mode="decoder", max_batch=4, max_new_tokens=16,
        pad_buckets=(16, 32), decode_segment=2, prefill_chunk=8))
    rng = np.random.RandomState(0)
    try:
        print("\n-- serving API v2: request -> handle -> result --")
        eng.warmup(batch_sizes=[1, 2])    # compile outside the demo
        h1 = eng.generate(rng.randint(0, cfg.vocab_size, (12,)),  # lane 16
                          SamplingParams(temperature=0.7, top_k=16, seed=1),
                          request_id="stream-demo")
        h2 = None
        print("h1 tokens:", end=" ", flush=True)
        for i, tok in enumerate(h1):
            print(tok, end=" ", flush=True)
            if i == 2:        # h1 mid-decode: a 28-token prompt joins the
                h2 = eng.generate(        # bucket-32 lane, prefilling in
                    rng.randint(0, cfg.vocab_size, (28,)))   # 8-tok chunks
        print()
        r1, r2 = h1.result(600), h2.result(600)
        for name, r in (("h1", r1), ("h2", r2)):
            t = r.timing
            print(f"{name}: {len(r.tokens)} tokens finish={r.finish_reason} "
                  f"queue {t.queue_s * 1e3:.0f}ms | prefill "
                  f"{t.prefill_s * 1e3:.0f}ms | decode "
                  f"{t.decode_s * 1e3:.0f}ms")
        m = eng.metrics()
        print(f"mid-decode joins: {m['joins_mid_flight']} | segments: "
              f"{m['decode_segments']} | prefill chunks: "
              f"{m['prefill_chunks']} | mean occupancy: "
              f"{m['batch_occupancy_mean']:.2f}")
        for bucket, lane in sorted(m["lanes"].items()):
            print(f"lane {bucket}: segments={lane['decode_segments']} "
                  f"tier_hist={lane['tier_hist']} "
                  f"compact_segments={lane['compact_segments']}")
    finally:
        eng.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-ns", type=int, default=64,
                    help="top of the 2^N ladder (paper: 512; CPU host "
                         "default: 64)")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-inflight", type=int, default=16)
    ap.add_argument("--decoder-demo", action="store_true",
                    help="also run the serving-API-v2 streaming/continuous-"
                         "batching walkthrough")
    args = ap.parse_args()

    cfg = get_config("gector-base", smoke=True)
    corpus = GECCorpus(CorpusConfig(vocab_size=cfg.vocab_size,
                                    edit_words=256, seed=5))
    params = init_gector(cfg, jax.random.PRNGKey(0), corpus.vocab)
    sentences = [src for src, _, _ in corpus.generate(256)]
    ladder = [n for n in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
              if n <= args.max_ns]

    print(f"== GECToR-small MLaaS POC on this host "
          f"(model {sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M "
          f"params) ==")

    print("\n-- baseline engine (paper's setup: no admission control) --")
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder",
                                     max_batch=args.max_batch))
    try:
        cells = run_ladder(eng, sentences, ladder=ladder,
                           repeats=args.repeats)
    finally:
        eng.close()
    print(format_table(cells))
    base_metrics = {c.ns: c for c in cells}

    print("\n-- with admission-control queue (the paper's §4 proposal) --")
    eng = ServingEngine(cfg, params,
                        EngineConfig(mode="encoder",
                                     max_batch=args.max_batch,
                                     max_inflight=args.max_inflight))
    try:
        cells_q = run_ladder(eng, sentences, ladder=ladder,
                             repeats=args.repeats)
        admission = eng.metrics()
    finally:
        eng.close()
    print(format_table(cells_q))
    print(f"\nadmission stats: peak queue "
          f"{admission.get('admission_peak_queue')} | total wait "
          f"{admission.get('admission_wait_total_s', 0):.2f}s")

    print("\n-- paper-trend checks on this host --")
    top = cells[-1]
    print(f"latency grows with NS: "
          f"{'OK' if top.latency_s > cells[0].latency_s else 'NO'} "
          f"({cells[0].latency_s:.2f}s @1 -> {top.latency_s:.2f}s "
          f"@{top.ns})")
    spread = max(c.ram_pct for c in cells) - min(c.ram_pct for c in cells)
    print(f"RAM flat across ladder (paper finding 4): "
          f"{'OK' if spread < 10 else 'NO'} (spread {spread:.1f} pp)")

    if args.decoder_demo:
        decoder_demo()


if __name__ == "__main__":
    main()
