"""Quickstart: serve any registry architecture end-to-end through the v2
serving API — multi-lane continuous batching, chunked prefill, streaming,
and occupancy-adaptive decode-segment widths, on CPU with the reduced
(smoke) config. CI runs this as the examples smoke check.

  PYTHONPATH=src python examples/quickstart.py --arch qwen2-0.5b
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import init_params
from repro.serving import (EngineConfig, GenerationRequest, SamplingParams,
                           ServingEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    full = get_config(args.arch)
    print(f"arch={full.name} [{full.arch_type}] "
          f"{full.n_layers}L d={full.d_model} heads={full.n_heads}/"
          f"{full.n_kv_heads} vocab={full.vocab_size}")
    print(f"full-size params: {full.param_count()/1e9:.2f}B "
          f"(active {full.active_param_count()/1e9:.2f}B)")
    print(f"running reduced variant: {cfg.n_layers}L d={cfg.d_model} "
          f"pattern={cfg.pattern}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"reduced params: {n_params/1e6:.2f}M")

    # Two pad buckets -> two scheduling lanes; prompts longer than
    # prefill_chunk tokens prefill chunk-by-chunk, interleaved with decode
    # segments; segment widths track lane occupancy (the default).
    eng = ServingEngine(cfg, params, EngineConfig(
        mode="decoder", max_batch=4, max_new_tokens=args.max_new_tokens,
        pad_buckets=(16, 32), decode_segment=2, prefill_chunk=8))
    rng = np.random.RandomState(0)
    try:
        print("\ncompiling every (bucket x join size x width tier) ...")
        eng.warmup(batch_sizes=[1, 2])
        # warmup primes the greedy graphs; sampling (temperature > 0) is a
        # separate jit variant — warm it with one throwaway request
        eng.generate(rng.randint(0, cfg.vocab_size, (5,)),
                     SamplingParams(temperature=0.7, top_k=16,
                                    seed=1)).result(600)
        eng.window()                       # measured span starts here

        # a typed request: prompt + per-request sampling, streamed
        # (7 tokens: whole-prompt prefill — the sampled chunked-prefill
        # graph is the one variant the throwaway above did not warm)
        h1 = eng.generate(GenerationRequest(
            tokens=rng.randint(0, cfg.vocab_size, (7,)),     # bucket 16
            sampling=SamplingParams(temperature=0.7, top_k=16, seed=1),
            request_id="stream-demo"))
        h2 = None
        print("h1 tokens:", end=" ", flush=True)
        for i, tok in enumerate(h1):       # streams per decode segment
            print(tok, end=" ", flush=True)
            if i == 2:                     # h1 is mid-decode: a long
                h2 = eng.generate(         # prompt joins the OTHER lane,
                    rng.randint(0, cfg.vocab_size, (30,)))   # chunked
        print()
        if h2 is None:                     # --max-new-tokens < 3: h1's
            h2 = eng.generate(             # stream ended before the mid-
                rng.randint(0, cfg.vocab_size, (30,)))   # decode join
        r1, r2 = h1.result(600), h2.result(600)
        for name, r in (("h1", r1), ("h2", r2)):
            t = r.timing
            print(f"{name}: {len(r.tokens)} tokens finish={r.finish_reason} "
                  f"queue {t.queue_s * 1e3:.0f}ms | prefill "
                  f"{t.prefill_s * 1e3:.0f}ms | decode "
                  f"{t.decode_s * 1e3:.0f}ms")

        w = eng.window()
        print(f"\nwindow: requests={w['requests']} "
              f"joins_mid_flight={w['joins_mid_flight']} "
              f"prefill_chunks={w['prefill_chunks']} "
              f"jit_compiles={w['jit_compiles']} (0 = compile-clean)")
        for bucket, lane in sorted(w["lanes"].items()):
            print(f"  lane {bucket}: segments={lane['decode_segments']} "
                  f"occupancy_mean={lane['occupancy_mean']:.2f} "
                  f"tier_hist={lane['tier_hist']} "
                  f"compact_segments={lane['compact_segments']}")
        assert r2.finish_reason == "length"
        assert w["prefill_chunks"] >= 4    # 30-token prompt, 8-token chunks
        assert w["jit_compiles"] == 0      # the measured span compiled
        #                                    nothing: warmup covered it
        print("\nquickstart OK: v2 API, lanes, chunked prefill, "
              "adaptive widths all exercised")
    finally:
        eng.close()


if __name__ == "__main__":
    main()
