"""Quickstart: pick any assigned architecture, run a forward pass and a few
greedy decode steps on CPU with the reduced (smoke) config.

  PYTHONPATH=src python examples/quickstart.py --arch gemma2-27b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import decode_step, forward, init_params, make_caches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    full = get_config(args.arch)
    print(f"arch={full.name} [{full.arch_type}] "
          f"{full.n_layers}L d={full.d_model} heads={full.n_heads}/"
          f"{full.n_kv_heads} vocab={full.vocab_size}")
    print(f"full-size params: {full.param_count()/1e9:.2f}B "
          f"(active {full.active_param_count()/1e9:.2f}B)")
    print(f"running reduced variant: {cfg.n_layers}L d={cfg.d_model} "
          f"pattern={cfg.pattern}")

    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"reduced params: {n_params/1e6:.2f}M")

    toks = jax.random.randint(rng, (1, args.tokens), 0, cfg.vocab_size)
    kw = {}
    if cfg.enc_layers:
        kw["enc_tokens_embeds"] = jnp.zeros((1, cfg.enc_seq_len,
                                             cfg.d_model), jnp.float32)
    if cfg.vis_tokens:
        kw["prefix_embeds"] = jnp.zeros((1, cfg.vis_tokens, cfg.d_model),
                                        jnp.float32)
    logits, _, _ = forward(cfg, params, tokens=toks, **kw)
    print(f"prefill logits: {logits.shape}, "
          f"ppl(random)={float(jnp.exp(-jax.nn.log_softmax(logits).mean())):.1f}")

    caches = make_caches(cfg, 1, 64, dtype=jnp.float32)
    tok = toks[:, :1]
    out = []
    ekw = {k: v for k, v in kw.items() if k == "enc_tokens_embeds"}
    for t in range(8):
        pos = jnp.full((1, 1), t, jnp.int32)
        logits, caches, _ = decode_step(cfg, params, tok, pos, caches, **ekw)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode (untrained):", out)


if __name__ == "__main__":
    main()
